//! `drf supervise` — the autonomous cluster control plane.
//!
//! The supervisor owns a `drf shard` output directory: it boots the
//! fleet (optionally an objstore replica set, then one `drf worker`
//! per shard pack), publishes their addresses in `cluster.json`, and
//! keeps the fleet alive. Every tick it probes each child — process
//! liveness (`try_wait`), the cheap pre-handshake TimeSync RPC, and
//! `GET /healthz` on the child's metrics port — and feeds the results
//! through a **pure decision core** ([`decide`]): a process is only
//! declared dead after `fail_threshold` consecutive failed probes
//! (flap damping), restarts are rate-limited by a cooldown, and a
//! crash-looping process escalates from restart-in-place to a
//! reschedule onto a `--spare-hosts` pool.
//!
//! Coordination with a running leader needs **no new RPC surface**:
//! the supervisor is the single writer of `cluster.json` and bumps its
//! `version` on every rewrite. The leader re-reads the file between
//! trees ([`ClusterPool::poll_topology`]) and re-reads worker
//! *addresses* mid-tree while a reconnect waits out a restart, so a
//! rescheduled worker is rewired into a tree already being built; the
//! recovery layer replays the level-update log into the replacement
//! and the forest stays bit-identical (`tests/cluster.rs`).
//!
//! **Elastic drain** ([`drain_worker`]) re-shards a worker out of the
//! fleet mid-run: its redundancy-1 column files are copied onto the
//! least-loaded surviving shards, every shard manifest is rewritten,
//! and the cluster manifest is atomically replaced with the victim
//! owning nothing. The forest is topology-invariant — per-level column
//! assignment only routes which replica *scans* a column — so a drain
//! adopted at a tree boundary cannot change the model. The drained
//! process is deliberately left running: the tree in flight still
//! scans it until the leader adopts the new version.
//!
//! A `--control-addr` listener accepts one-line commands
//! (`status`, `kill N`, `kill objstore [R]`, `drain N`, `quit`) so
//! chaos drills and operators can script the control plane.
//!
//! [`ClusterPool::poll_topology`]: super::engine::ClusterPool::poll_topology

use super::manifest::{ClusterManifest, ShardColumn, ShardManifest};
use crate::util::Json;
use crate::Result;
use anyhow::{bail, ensure, Context};
use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------
// Pure decision core
// ---------------------------------------------------------------------

/// Tunables of the supervisor's failure-handling policy.
#[derive(Debug, Clone)]
pub struct SupervisePolicy {
    /// Consecutive failed probes before a process is declared dead. A
    /// single dropped probe (GC pause, packet loss) never restarts a
    /// slow-but-alive worker.
    pub fail_threshold: u32,
    /// Minimum time between two restarts of the same process; failed
    /// probes inside the window are damped — the replacement may still
    /// be loading its pack.
    pub restart_cooldown_ms: u64,
    /// In-place restarts tolerated within [`restart_window_ms`] before
    /// the process is rescheduled onto a spare host instead (the host
    /// itself is presumed bad).
    ///
    /// [`restart_window_ms`]: SupervisePolicy::restart_window_ms
    pub max_restarts_in_place: usize,
    /// Sliding window over which in-place restarts are counted.
    pub restart_window_ms: u64,
}

impl Default for SupervisePolicy {
    fn default() -> Self {
        Self {
            fail_threshold: 2,
            restart_cooldown_ms: 1_000,
            max_restarts_in_place: 3,
            restart_window_ms: 60_000,
        }
    }
}

/// Rolling probe/restart history of one supervised process — the only
/// state [`decide`] reads and writes, so the policy is testable with a
/// fake clock.
#[derive(Debug, Clone, Default)]
pub struct ProcHealth {
    consecutive_failures: u32,
    /// In-place restart times inside the sliding window.
    restarts_ms: Vec<u64>,
    last_restart_ms: Option<u64>,
}

/// What the policy wants done with one process after one probe round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SuperviseAction {
    /// Healthy, or not yet provably dead, or inside a restart cooldown.
    Keep,
    /// Start a replacement on the same host.
    RestartInPlace,
    /// The process crash-looped through its in-place budget; start the
    /// replacement on a spare host.
    Reschedule,
}

/// The supervisor's brain as a pure function of `(history, policy,
/// probe result, clock)` — fully deterministic, so every damping and
/// escalation rule is unit-tested with a fake clock.
///
/// Rules, in order: a successful probe resets the failure streak;
/// fewer than [`SupervisePolicy::fail_threshold`] consecutive failures
/// keep the process; a failure streak inside the restart cooldown is
/// damped; otherwise a restart fires — in place, unless the window
/// already holds [`SupervisePolicy::max_restarts_in_place`] of them,
/// which escalates to [`SuperviseAction::Reschedule`] (and resets the
/// window for the new host).
pub fn decide(
    h: &mut ProcHealth,
    policy: &SupervisePolicy,
    alive: bool,
    now_ms: u64,
) -> SuperviseAction {
    if alive {
        h.consecutive_failures = 0;
        return SuperviseAction::Keep;
    }
    h.consecutive_failures += 1;
    if h.consecutive_failures < policy.fail_threshold {
        return SuperviseAction::Keep;
    }
    if let Some(last) = h.last_restart_ms {
        if now_ms.saturating_sub(last) < policy.restart_cooldown_ms {
            return SuperviseAction::Keep;
        }
    }
    h.restarts_ms
        .retain(|&t| now_ms.saturating_sub(t) < policy.restart_window_ms);
    h.consecutive_failures = 0;
    h.last_restart_ms = Some(now_ms);
    if h.restarts_ms.len() >= policy.max_restarts_in_place {
        h.restarts_ms.clear();
        return SuperviseAction::Reschedule;
    }
    h.restarts_ms.push(now_ms);
    SuperviseAction::RestartInPlace
}

// ---------------------------------------------------------------------
// Elastic drain
// ---------------------------------------------------------------------

/// Atomically replace `path` with `manifest` (write-to-temp + rename),
/// so a leader polling the file mid-write never reads a torn manifest.
pub fn save_manifest_atomic(manifest: &ClusterManifest, path: &Path) -> Result<()> {
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, manifest.to_json().to_string())
        .with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    Ok(())
}

/// Re-shard worker `victim` out of the fleet: every column only it
/// owns is copied (raw + presorted file, checksums unchanged) onto the
/// least-loaded surviving shard (ties to the lowest shard id —
/// deterministic), the affected pack manifests are rewritten with
/// their column lists kept sorted, the victim's pack manifest is
/// emptied, and `cluster.json` is atomically replaced with its version
/// bumped. Columns that other shards already replicate simply lose one
/// replica. Returns the published manifest.
///
/// The victim *process* is untouched — a tree in flight still scans it
/// until the leader adopts the new version at the next tree boundary.
pub fn drain_worker(cluster_dir: &Path, victim: usize) -> Result<ClusterManifest> {
    let path = cluster_dir.join(ClusterManifest::FILE);
    let mut cluster = ClusterManifest::load(&path)?;
    ensure!(
        victim < cluster.shards.len(),
        "no shard {victim} in a {}-shard cluster",
        cluster.shards.len()
    );
    ensure!(
        !cluster.shards[victim].columns.is_empty(),
        "shard {victim} is already drained"
    );
    let mut loads: Vec<usize> = cluster.shards.iter().map(|e| e.columns.len()).collect();
    // Destinations: surviving shards that still own columns (an
    // already-drained shard stays drained — handing columns back would
    // silently undo an earlier drain).
    let eligible: Vec<usize> = (0..cluster.shards.len())
        .filter(|&s| s != victim && loads[s] > 0)
        .collect();
    ensure!(
        !eligible.is_empty(),
        "no surviving shard left to take over shard {victim}'s columns"
    );

    let victim_dir = cluster_dir.join(&cluster.shards[victim].dir);
    let mut victim_manifest = ShardManifest::load(&victim_dir)?;
    let mut moved: BTreeMap<usize, Vec<ShardColumn>> = BTreeMap::new();
    for j in cluster.shards[victim].columns.clone() {
        let replicated = cluster
            .shards
            .iter()
            .enumerate()
            .any(|(s, e)| s != victim && e.columns.contains(&j));
        if replicated {
            continue;
        }
        let dest = *eligible
            .iter()
            .min_by_key(|&&s| (loads[s], s))
            .expect("eligible is non-empty");
        loads[dest] += 1;
        let col = victim_manifest
            .columns
            .iter()
            .find(|c| c.index == j)
            .with_context(|| format!("shard {victim}'s pack manifest is missing column {j}"))?
            .clone();
        let dst_dir = cluster_dir.join(&cluster.shards[dest].dir);
        // Pack files are named by *global* column index, so a copy
        // between shard directories cannot collide.
        std::fs::copy(victim_dir.join(&col.file), dst_dir.join(&col.file))
            .with_context(|| format!("moving column {j} to shard {dest}"))?;
        if let Some(sf) = &col.sorted_file {
            std::fs::copy(victim_dir.join(sf), dst_dir.join(sf))
                .with_context(|| format!("moving presorted column {j} to shard {dest}"))?;
        }
        cluster.shards[dest].columns.push(j);
        moved.entry(dest).or_default().push(col);
    }

    for (dest, cols) in moved {
        let dir = cluster_dir.join(&cluster.shards[dest].dir);
        let mut m = ShardManifest::load(&dir)?;
        m.columns.extend(cols);
        // The leader validates a worker's inventory in ascending global
        // order; keep the pack manifest (and the cluster entry) sorted.
        m.columns.sort_by_key(|c| c.index);
        m.save(&dir)?;
        cluster.shards[dest].columns.sort_unstable();
    }
    victim_manifest.columns.clear();
    victim_manifest.save(&victim_dir)?;
    cluster.shards[victim].columns.clear();
    cluster.version += 1;
    // Sanity before publishing: every column must still have an owner.
    cluster
        .topology()
        .context("drained manifest no longer forms a valid topology")?;
    save_manifest_atomic(&cluster, &path)?;
    crate::telemetry::counter("drf_supervisor_drains_total").inc();
    Ok(cluster)
}

// ---------------------------------------------------------------------
// Probes
// ---------------------------------------------------------------------

fn probe_tcp(
    addr: &str,
    timeout: Duration,
    request: &[u8],
    accept: impl Fn(&[u8]) -> bool,
) -> bool {
    use crate::coordinator::wire::{read_frame, write_frame};
    let Ok(sa) = addr.parse::<SocketAddr>() else {
        return false;
    };
    let Ok(mut stream) = TcpStream::connect_timeout(&sa, timeout) else {
        return false;
    };
    if stream.set_read_timeout(Some(timeout)).is_err()
        || stream.set_write_timeout(Some(timeout)).is_err()
    {
        return false;
    }
    if write_frame(&mut stream, request).is_err() {
        return false;
    }
    match read_frame(&mut stream) {
        Ok(f) => accept(&f),
        Err(_) => false,
    }
}

/// Cheap worker liveness: the pre-handshake TimeSync RPC round trip.
pub fn probe_worker(addr: &str, timeout: Duration) -> bool {
    use crate::coordinator::wire::{decode_response, encode_request, Request, Response};
    probe_tcp(addr, timeout, &encode_request(&Request::TimeSync), |f| {
        matches!(decode_response(f), Ok(Response::TimeSync(_)))
    })
}

/// Cheap objstore liveness: its TimeSync request round trip.
pub fn probe_objstore(addr: &str, timeout: Duration) -> bool {
    use crate::data::objserve::{decode_response, encode_request, ObjRequest, ObjResponse};
    probe_tcp(addr, timeout, &encode_request(&ObjRequest::TimeSync), |f| {
        matches!(decode_response(f), Ok(ObjResponse::TimeSync(_)))
    })
}

/// `GET /healthz` on a metrics endpoint; true iff it answers 200 with
/// `"ok":true`. Informational — RPC liveness decides restarts, this
/// feeds the `drf_supervisor_healthz_failures_total` counter.
pub fn probe_healthz(addr: &str, timeout: Duration) -> bool {
    let Ok(sa) = addr.parse::<SocketAddr>() else {
        return false;
    };
    let Ok(mut stream) = TcpStream::connect_timeout(&sa, timeout) else {
        return false;
    };
    if stream.set_read_timeout(Some(timeout)).is_err()
        || stream.set_write_timeout(Some(timeout)).is_err()
    {
        return false;
    }
    if stream
        .write_all(format!("GET /healthz HTTP/1.0\r\nHost: {addr}\r\n\r\n").as_bytes())
        .is_err()
    {
        return false;
    }
    let mut response = String::new();
    if stream.read_to_string(&mut response).is_err() {
        return false;
    }
    response.starts_with("HTTP/1.0 200") && response.contains("\"ok\":true")
}

// ---------------------------------------------------------------------
// Supervisor runtime
// ---------------------------------------------------------------------

/// How `drf supervise` runs its fleet.
#[derive(Debug, Clone)]
pub struct SuperviseOptions {
    /// Probe interval.
    pub interval: Duration,
    /// The failure-handling policy driving [`decide`].
    pub policy: SupervisePolicy,
    /// Hosts (or `host:port` bind addresses) rescheduled processes
    /// move onto, round robin. Empty degrades a reschedule to a
    /// restart in place.
    pub spare_hosts: Vec<String>,
    /// Bind address of the one-line command listener (`status`,
    /// `kill N`, `kill objstore [R]`, `drain N`, `quit`).
    pub control_addr: Option<String>,
    /// JSONL action log (one line per spawn/restart/reschedule/drain).
    pub action_log: Option<PathBuf>,
    /// Objstore replicas to run over the cluster directory. All serve
    /// the *same* directory — byte-identical by construction, and a
    /// drain's rewritten packs are visible through every replica —
    /// while workers hold the whole list for client-side failover.
    /// `0` = no objstores; workers load their packs from local disk.
    pub objstore_replicas: usize,
    /// Extra arguments appended to every spawned `drf worker` (e.g.
    /// `--scan-threads 2`, `--preload`).
    pub worker_args: Vec<String>,
    /// Per-child `--trace-out` files are written under this directory.
    pub trace_dir: Option<PathBuf>,
    /// The `drf` binary to spawn children from (default: this one).
    pub binary: Option<PathBuf>,
}

impl Default for SuperviseOptions {
    fn default() -> Self {
        Self {
            interval: Duration::from_millis(500),
            policy: SupervisePolicy::default(),
            spare_hosts: Vec::new(),
            control_addr: None,
            action_log: None,
            objstore_replicas: 0,
            worker_args: Vec::new(),
            trace_dir: None,
            binary: None,
        }
    }
}

/// Milliseconds since the Unix epoch — the supervisor's action clock.
fn now_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// One spawned child plus the stdout line stream its ready lines (and
/// nothing else) arrive on.
struct Supervised {
    child: Child,
    lines: Receiver<String>,
    /// The serving address parsed from the child's ready line.
    addr: String,
    /// The child's `/metrics` address (second ready line).
    metrics_addr: String,
    health: ProcHealth,
    /// A drained worker idles on purpose; it is probed but never
    /// restarted, and takes no new traffic once the leader adopts the
    /// drained topology.
    drained: bool,
}

impl Supervised {
    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for Supervised {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Spawn `binary args...` with piped stdout and a reader thread that
/// forwards every line to the returned channel (and keeps draining
/// after the ready lines, so the child can never block on a full
/// pipe). stderr passes through to the supervisor's own.
fn spawn_child(binary: &Path, args: &[String]) -> Result<(Child, Receiver<String>)> {
    let mut child = Command::new(binary)
        .args(args)
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .with_context(|| format!("spawning {} {}", binary.display(), args.join(" ")))?;
    let stdout = child.stdout.take().context("child stdout was not piped")?;
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::Builder::new()
        .name("drf-supervise-stdout".into())
        .spawn(move || {
            for line in BufReader::new(stdout).lines() {
                match line {
                    Ok(l) => {
                        if tx.send(l).is_err() {
                            break;
                        }
                    }
                    Err(_) => break,
                }
            }
        })?;
    Ok((child, rx))
}

/// Wait for a line containing `needle` and return its last
/// whitespace-separated token (the address in every `drf` ready line).
fn wait_ready(lines: &Receiver<String>, needle: &str, timeout: Duration) -> Result<String> {
    let deadline = Instant::now() + timeout;
    loop {
        let now = Instant::now();
        ensure!(
            now < deadline,
            "child did not print a '{needle}' ready line within {timeout:?}"
        );
        match lines.recv_timeout(deadline - now) {
            Ok(line) if line.contains(needle) => {
                let addr = line
                    .split_whitespace()
                    .last()
                    .map(str::to_string)
                    .unwrap_or_default();
                ensure!(!addr.is_empty(), "malformed ready line '{line}'");
                return Ok(addr);
            }
            Ok(_) => continue,
            // Timeout loops back to the deadline check above.
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                bail!("child exited before printing a '{needle}' ready line")
            }
        }
    }
}

/// `host` or `host:port` → a bind address (`host:0` when no port).
fn bind_addr(host: &str) -> String {
    if host.contains(':') {
        host.to_string()
    } else {
        format!("{host}:0")
    }
}

/// The host part of `host:port`.
fn host_of(addr: &str) -> &str {
    addr.rsplit_once(':').map(|(h, _)| h).unwrap_or(addr)
}

/// The running control plane: fleet handles, the manifest it owns, and
/// the policy state. Constructed and driven by [`Supervisor::run`].
struct Fleet<'a> {
    cluster_dir: &'a Path,
    manifest_path: PathBuf,
    manifest: ClusterManifest,
    binary: PathBuf,
    opts: &'a SuperviseOptions,
    workers: Vec<Supervised>,
    objstores: Vec<Supervised>,
    spare_next: usize,
    spawn_seq: u64,
    log: Option<std::fs::File>,
}

impl Fleet<'_> {
    fn objstore_list(&self) -> String {
        self.objstores
            .iter()
            .map(|o| o.addr.clone())
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Publish the manifest under the next version.
    fn commit(&mut self) -> Result<()> {
        self.manifest.version += 1;
        save_manifest_atomic(&self.manifest, &self.manifest_path)?;
        crate::telemetry::gauge("drf_supervisor_manifest_version").set(self.manifest.version);
        Ok(())
    }

    fn log_action(&mut self, action: &str, role: &str, id: usize, detail: &str) {
        crate::telemetry::counter_with("drf_supervisor_actions_total", &[("action", action)])
            .inc();
        let Some(f) = &mut self.log else { return };
        let mut o = Json::object();
        o.set("t_ms", Json::from_u64(now_ms()))
            .set("action", Json::Str(action.into()))
            .set("role", Json::Str(role.into()))
            .set("id", Json::from_usize(id))
            .set("detail", Json::Str(detail.into()));
        let _ = writeln!(f, "{}", o.to_string());
        let _ = f.flush();
    }

    fn trace_arg(&mut self, name: &str) -> Vec<String> {
        let Some(dir) = &self.opts.trace_dir else {
            return Vec::new();
        };
        self.spawn_seq += 1;
        let path = dir.join(format!("{name}.{}.jsonl", self.spawn_seq));
        vec!["--trace-out".into(), path.display().to_string()]
    }

    fn spawn_objstore(&mut self, host: &str) -> Result<Supervised> {
        let mut args = vec![
            "objstore".into(),
            "--dir".into(),
            self.cluster_dir.display().to_string(),
            "--addr".into(),
            bind_addr(host),
            "--metrics-addr".into(),
            "127.0.0.1:0".into(),
        ];
        args.extend(self.trace_arg("objstore"));
        let (child, lines) = spawn_child(&self.binary, &args)?;
        let mut sup = Supervised {
            child,
            lines,
            addr: String::new(),
            metrics_addr: String::new(),
            health: ProcHealth::default(),
            drained: false,
        };
        sup.addr = wait_ready(&sup.lines, ": serving", READY_TIMEOUT)?;
        sup.metrics_addr = wait_ready(&sup.lines, "metrics on", READY_TIMEOUT)?;
        Ok(sup)
    }

    fn spawn_worker(&mut self, s: usize, host: &str) -> Result<Supervised> {
        let entry = &self.manifest.shards[s];
        let mut args = vec![
            "worker".into(),
            "--addr".into(),
            bind_addr(host),
            "--metrics-addr".into(),
            "127.0.0.1:0".into(),
        ];
        if self.objstores.is_empty() {
            args.push("--shard".into());
            args.push(self.cluster_dir.join(&entry.dir).display().to_string());
        } else {
            // Remote pack: `--shard` is the prefix under the objstore
            // root; the worker holds the whole replica list.
            args.push("--shard".into());
            args.push(entry.dir.clone());
            args.push("--object-store".into());
            args.push(self.objstore_list());
        }
        args.extend(self.opts.worker_args.iter().cloned());
        args.extend(self.trace_arg(&format!("worker_{s}")));
        let (child, lines) = spawn_child(&self.binary, &args)?;
        let mut sup = Supervised {
            child,
            lines,
            addr: String::new(),
            metrics_addr: String::new(),
            health: ProcHealth::default(),
            drained: false,
        };
        sup.addr = wait_ready(&sup.lines, "listening on", READY_TIMEOUT)?;
        sup.metrics_addr = wait_ready(&sup.lines, "metrics on", READY_TIMEOUT)?;
        Ok(sup)
    }

    /// Next reschedule target, round robin over the spare pool; falls
    /// back to `current` when no spares were given.
    fn next_spare(&mut self, current: &str) -> String {
        if self.opts.spare_hosts.is_empty() {
            return current.to_string();
        }
        let host = self.opts.spare_hosts[self.spare_next % self.opts.spare_hosts.len()].clone();
        self.spare_next += 1;
        host
    }

    /// Replace worker `s` with a fresh process on `host`, carry its
    /// policy history over, publish the new address. A spawn failure
    /// leaves the old entry in place — the next probe round retries
    /// after the cooldown.
    fn respawn_worker(&mut self, s: usize, host: &str, action: &str) -> Result<()> {
        let _span = crate::span!("supervisor_respawn", tree = s);
        self.workers[s].kill();
        let mut fresh = self.spawn_worker(s, host)?;
        fresh.health = std::mem::take(&mut self.workers[s].health);
        fresh.drained = self.workers[s].drained;
        self.workers[s] = fresh;
        self.manifest.workers[s] = self.workers[s].addr.clone();
        self.commit()?;
        let detail = format!("{} v{}", self.workers[s].addr, self.manifest.version);
        self.log_action(action, "worker", s, &detail);
        crate::telemetry::counter("drf_supervisor_restarts_total").inc();
        Ok(())
    }

    /// Replace objstore `r` on `host`. Workers keep their spawn-time
    /// replica list, so they reach the survivors by client-side
    /// failover; the new address reaches them on their next respawn or
    /// pack reload.
    fn respawn_objstore(&mut self, r: usize, host: &str, action: &str) -> Result<()> {
        self.objstores[r].kill();
        let mut fresh = self.spawn_objstore(host)?;
        fresh.health = std::mem::take(&mut self.objstores[r].health);
        self.objstores[r] = fresh;
        self.manifest.objstores[r] = self.objstores[r].addr.clone();
        self.commit()?;
        let detail = format!("{} v{}", self.objstores[r].addr, self.manifest.version);
        self.log_action(action, "objstore", r, &detail);
        crate::telemetry::counter("drf_supervisor_restarts_total").inc();
        Ok(())
    }

    /// One probe round over the whole fleet.
    fn probe_round(&mut self) {
        let policy = self.opts.policy.clone();
        for s in 0..self.workers.len() {
            let exited = matches!(self.workers[s].child.try_wait(), Ok(Some(_)));
            let (addr, metrics_addr, drained) = {
                let w = &self.workers[s];
                (w.addr.clone(), w.metrics_addr.clone(), w.drained)
            };
            let alive = !exited && probe_worker(&addr, PROBE_TIMEOUT);
            crate::telemetry::counter("drf_supervisor_probes_total").inc();
            if !alive {
                crate::telemetry::counter("drf_supervisor_probe_failures_total").inc();
            } else if !probe_healthz(&metrics_addr, PROBE_TIMEOUT) {
                crate::telemetry::counter("drf_supervisor_healthz_failures_total").inc();
            }
            if drained {
                continue; // never restarted; the fleet routes around it
            }
            match decide(&mut self.workers[s].health, &policy, alive, now_ms()) {
                SuperviseAction::Keep => {}
                SuperviseAction::RestartInPlace => {
                    let host = host_of(&addr).to_string();
                    if let Err(e) = self.respawn_worker(s, &host, "restart") {
                        eprintln!("drf supervise: restart of worker {s} failed: {e:#}");
                    }
                }
                SuperviseAction::Reschedule => {
                    let host = self.next_spare(&addr);
                    if let Err(e) = self.respawn_worker(s, &host, "reschedule") {
                        eprintln!("drf supervise: reschedule of worker {s} failed: {e:#}");
                    }
                }
            }
        }
        for r in 0..self.objstores.len() {
            let exited = matches!(self.objstores[r].child.try_wait(), Ok(Some(_)));
            let addr = self.objstores[r].addr.clone();
            let alive = !exited && probe_objstore(&addr, PROBE_TIMEOUT);
            crate::telemetry::counter("drf_supervisor_probes_total").inc();
            if !alive {
                crate::telemetry::counter("drf_supervisor_probe_failures_total").inc();
            }
            match decide(&mut self.objstores[r].health, &policy, alive, now_ms()) {
                SuperviseAction::Keep => {}
                SuperviseAction::RestartInPlace => {
                    let host = host_of(&addr).to_string();
                    if let Err(e) = self.respawn_objstore(r, &host, "restart") {
                        eprintln!("drf supervise: restart of objstore {r} failed: {e:#}");
                    }
                }
                SuperviseAction::Reschedule => {
                    let host = self.next_spare(&addr);
                    if let Err(e) = self.respawn_objstore(r, &host, "reschedule") {
                        eprintln!("drf supervise: reschedule of objstore {r} failed: {e:#}");
                    }
                }
            }
        }
    }

    fn status(&self) -> String {
        let workers: Vec<String> = self
            .workers
            .iter()
            .enumerate()
            .map(|(s, w)| {
                let state = if w.drained { "drained" } else { "up" };
                format!("{s}:{} {state}", w.addr)
            })
            .collect();
        let objstores: Vec<String> = self
            .objstores
            .iter()
            .enumerate()
            .map(|(r, o)| format!("{r}:{}", o.addr))
            .collect();
        format!(
            "ok version={} workers=[{}] objstores=[{}]",
            self.manifest.version,
            workers.join(", "),
            objstores.join(", ")
        )
    }

    /// Execute one control command; the reply is a single `ok ...` or
    /// `err ...` line.
    fn handle_command(&mut self, line: &str) -> (String, bool) {
        let tokens: Vec<&str> = line.split_whitespace().collect();
        let reply = match tokens.as_slice() {
            ["status"] => self.status(),
            ["quit"] => return ("ok quitting".into(), true),
            ["kill", "objstore"] | ["kill", "objstore", _] => {
                let r = tokens.get(2).and_then(|t| t.parse().ok()).unwrap_or(0);
                if r >= self.objstores.len() {
                    format!("err no objstore {r}")
                } else {
                    self.objstores[r].kill();
                    self.log_action("kill", "objstore", r, "control");
                    format!("ok killed objstore {r}")
                }
            }
            ["kill", n] => match n.parse::<usize>() {
                Ok(s) if s < self.workers.len() => {
                    self.workers[s].kill();
                    self.log_action("kill", "worker", s, "control");
                    format!("ok killed worker {s}")
                }
                _ => format!("err no worker {n}"),
            },
            ["drain", n] => match n.parse::<usize>() {
                Ok(s) if s < self.workers.len() => match drain_worker(self.cluster_dir, s) {
                    Ok(m) => {
                        // The process stays up for the tree in flight;
                        // it only stops being restarted.
                        self.manifest = m;
                        self.workers[s].drained = true;
                        let v = self.manifest.version;
                        self.log_action("drain", "worker", s, &format!("v{v}"));
                        format!("ok drained worker {s} version={v}")
                    }
                    Err(e) => format!("err drain of worker {s} failed: {e:#}"),
                },
                _ => format!("err no worker {n}"),
            },
            _ => format!(
                "err unknown command '{line}' (status|kill N|kill objstore [R]|drain N|quit)"
            ),
        };
        (reply, false)
    }
}

/// How long a spawned child may take to print its ready lines (a
/// worker verifies pack checksums before listening).
const READY_TIMEOUT: Duration = Duration::from_secs(120);
/// Per-probe connect/RPC timeout.
const PROBE_TIMEOUT: Duration = Duration::from_millis(1_500);

/// The `drf supervise` entry point.
pub struct Supervisor;

impl Supervisor {
    /// Boot and babysit the fleet for the cluster under `cluster_dir`
    /// until a `quit` control command arrives. Children's ready lines
    /// are consumed internally; this process's own stdout prints a
    /// fleet summary line once every child is up and — with a control
    /// listener — `control on ADDR`.
    pub fn run(cluster_dir: &Path, opts: &SuperviseOptions) -> Result<()> {
        let manifest_path = cluster_dir.join(ClusterManifest::FILE);
        let manifest = ClusterManifest::load(&manifest_path)?;
        let binary = match &opts.binary {
            Some(b) => b.clone(),
            None => std::env::current_exe().context("locating the drf binary")?,
        };
        let log = match &opts.action_log {
            Some(p) => Some(
                std::fs::OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(p)
                    .with_context(|| format!("opening action log {}", p.display()))?,
            ),
            None => None,
        };
        let num_shards = manifest.shards.len();
        let mut fleet = Fleet {
            cluster_dir,
            manifest_path,
            manifest,
            binary,
            opts,
            workers: Vec::with_capacity(num_shards),
            objstores: Vec::with_capacity(opts.objstore_replicas),
            spare_next: 0,
            spawn_seq: 0,
            log,
        };

        for _ in 0..opts.objstore_replicas {
            let o = fleet.spawn_objstore("127.0.0.1")?;
            let r = fleet.objstores.len();
            let detail = o.addr.clone();
            fleet.objstores.push(o);
            fleet.log_action("spawn", "objstore", r, &detail);
        }
        for s in 0..num_shards {
            let w = fleet.spawn_worker(s, "127.0.0.1")?;
            let detail = w.addr.clone();
            fleet.workers.push(w);
            fleet.log_action("spawn", "worker", s, &detail);
        }
        fleet.manifest.workers = fleet.workers.iter().map(|w| w.addr.clone()).collect();
        fleet.manifest.objstores = fleet.objstores.iter().map(|o| o.addr.clone()).collect();
        fleet.commit()?;
        crate::telemetry::gauge("drf_supervisor_children")
            .set((fleet.workers.len() + fleet.objstores.len()) as u64);

        println!(
            "drf supervise: {} workers{} up, manifest {} v{}",
            fleet.workers.len(),
            if fleet.objstores.is_empty() {
                String::new()
            } else {
                format!(" + {} objstore replicas", fleet.objstores.len())
            },
            fleet.manifest_path.display(),
            fleet.manifest.version
        );
        std::io::stdout().flush()?;

        // Control listener: one line-command per connection, queued for
        // the probe loop (which owns the fleet) to execute.
        type ControlQueue = Arc<Mutex<VecDeque<(String, TcpStream)>>>;
        let queue: ControlQueue = Arc::new(Mutex::new(VecDeque::new()));
        let _control = match &opts.control_addr {
            Some(addr) => {
                let listener = TcpListener::bind(addr)
                    .with_context(|| format!("binding control listener to {addr}"))?;
                println!("drf supervise: control on {}", listener.local_addr()?);
                std::io::stdout().flush()?;
                let q = queue.clone();
                let handle = std::thread::Builder::new()
                    .name("drf-supervise-control".into())
                    .spawn(move || {
                        for conn in listener.incoming() {
                            let Ok(stream) = conn else { continue };
                            let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
                            let Ok(reader) = stream.try_clone() else { continue };
                            let mut line = String::new();
                            if BufReader::new(reader).read_line(&mut line).is_ok() {
                                q.lock().unwrap().push_back((line, stream));
                            }
                        }
                    })?;
                Some(handle)
            }
            None => None,
        };

        loop {
            std::thread::sleep(opts.interval);
            let mut quit = false;
            loop {
                let cmd = queue.lock().unwrap().pop_front();
                let Some((line, mut stream)) = cmd else { break };
                let (reply, wants_quit) = fleet.handle_command(line.trim());
                let _ = writeln!(stream, "{reply}");
                quit |= wants_quit;
            }
            if quit {
                break;
            }
            let _span = crate::span!("supervisor_probe_round");
            fleet.probe_round();
        }
        // Drop order tears the fleet down (Supervised kills on drop).
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::shard::{write_shards, ShardOptions};
    use crate::cluster::worker::{load_shard, WorkerOptions};
    use crate::config::TopologyParams;
    use crate::data::io_stats::IoStats;
    use crate::data::synthetic::{Family, SyntheticSpec};

    fn policy() -> SupervisePolicy {
        SupervisePolicy {
            fail_threshold: 2,
            restart_cooldown_ms: 1_000,
            max_restarts_in_place: 2,
            restart_window_ms: 10_000,
        }
    }

    #[test]
    fn decide_damps_flaps_and_resets_on_success() {
        let p = policy();
        let mut h = ProcHealth::default();
        // One miss is a flap, not a death.
        assert_eq!(decide(&mut h, &p, false, 100), SuperviseAction::Keep);
        // A success resets the streak entirely.
        assert_eq!(decide(&mut h, &p, true, 200), SuperviseAction::Keep);
        assert_eq!(decide(&mut h, &p, false, 300), SuperviseAction::Keep);
        // Second consecutive miss crosses the threshold.
        assert_eq!(
            decide(&mut h, &p, false, 400),
            SuperviseAction::RestartInPlace
        );
    }

    #[test]
    fn decide_slow_but_alive_is_never_restarted() {
        let p = policy();
        let mut h = ProcHealth::default();
        for t in 0..1_000u64 {
            assert_eq!(decide(&mut h, &p, true, t * 100), SuperviseAction::Keep);
        }
    }

    #[test]
    fn decide_cooldown_then_escalation_to_reschedule() {
        let p = policy();
        let mut h = ProcHealth::default();
        // First death restarts in place at t=1000.
        assert_eq!(decide(&mut h, &p, false, 900), SuperviseAction::Keep);
        assert_eq!(
            decide(&mut h, &p, false, 1_000),
            SuperviseAction::RestartInPlace
        );
        // Still dead inside the cooldown: damped.
        assert_eq!(decide(&mut h, &p, false, 1_100), SuperviseAction::Keep);
        assert_eq!(decide(&mut h, &p, false, 1_500), SuperviseAction::Keep);
        // Past the cooldown: second in-place restart (budget is 2).
        assert_eq!(
            decide(&mut h, &p, false, 2_100),
            SuperviseAction::RestartInPlace
        );
        // Third death in the window escalates.
        assert_eq!(
            decide(&mut h, &p, false, 3_500),
            SuperviseAction::Reschedule
        );
        // The reschedule reset the in-place window for the new host.
        assert_eq!(
            decide(&mut h, &p, false, 5_000),
            SuperviseAction::RestartInPlace
        );
    }

    #[test]
    fn decide_forgets_restarts_outside_the_window() {
        let p = policy();
        let mut h = ProcHealth::default();
        for (i, t) in [1_000u64, 3_000].into_iter().enumerate() {
            assert_eq!(decide(&mut h, &p, false, t - 100), SuperviseAction::Keep, "{i}");
            assert_eq!(
                decide(&mut h, &p, false, t),
                SuperviseAction::RestartInPlace,
                "{i}"
            );
        }
        // Both restarts age out of the 10s window: in-place again, no
        // escalation.
        let t = 20_000;
        assert_eq!(decide(&mut h, &p, false, t - 100), SuperviseAction::Keep);
        assert_eq!(decide(&mut h, &p, false, t), SuperviseAction::RestartInPlace);
    }

    #[test]
    fn drain_moves_columns_deterministically_and_bumps_version() {
        let dir = crate::util::tempdir().unwrap();
        let ds = SyntheticSpec::new(Family::Xor { informative: 3 }, 150, 7, 19).generate();
        write_shards(
            &ds,
            &TopologyParams {
                num_splitters: Some(3),
                ..Default::default()
            },
            dir.path(),
            &ShardOptions::default(),
            IoStats::new(),
        )
        .unwrap();
        let before =
            ClusterManifest::load(&dir.path().join(ClusterManifest::FILE)).unwrap();
        let victim_cols = before.shards[0].columns.clone();
        assert!(!victim_cols.is_empty());

        let after = drain_worker(dir.path(), 0).unwrap();
        assert_eq!(after.version, before.version + 1);
        assert!(after.shards[0].columns.is_empty());
        // Every column still has exactly the coverage it needs: the
        // manifest forms a valid topology...
        after.topology().unwrap();
        // ...and the victim's columns moved to the least-loaded
        // survivors (ties to the lowest id), sorted ascending.
        for e in &after.shards {
            let mut sorted = e.columns.clone();
            sorted.sort_unstable();
            assert_eq!(e.columns, sorted, "shard {} entry must stay sorted", e.shard);
        }
        let total: usize = after.shards.iter().map(|e| e.columns.len()).sum();
        assert_eq!(total, ds.num_features(), "redundancy-1 columns all survive");

        // The re-cut packs load and re-verify their checksums, and the
        // victim's pack is empty but valid.
        for e in &after.shards {
            let pack = load_shard(&dir.path().join(&e.dir), &WorkerOptions::default()).unwrap();
            assert_eq!(pack.manifest.column_indices(), e.columns);
        }
        // Deterministic: both survivors hold 7 columns between them and
        // the placement is a pure function of the manifest, so a replay
        // from the same inputs gives the same map (spot-check: the
        // first victim column went to the lighter survivor).
        assert!(after.shards[1].columns.contains(&victim_cols[0]));

        // A second drain of the same shard is refused.
        let err = drain_worker(dir.path(), 0).unwrap_err();
        assert!(format!("{err:#}").contains("already drained"), "{err:#}");
    }

    #[test]
    fn drain_refuses_the_last_shard_standing() {
        let dir = crate::util::tempdir().unwrap();
        let ds = SyntheticSpec::new(Family::Majority { informative: 2 }, 80, 4, 7).generate();
        write_shards(
            &ds,
            &TopologyParams {
                num_splitters: Some(2),
                ..Default::default()
            },
            dir.path(),
            &ShardOptions::default(),
            IoStats::new(),
        )
        .unwrap();
        drain_worker(dir.path(), 1).unwrap();
        let err = drain_worker(dir.path(), 0).unwrap_err();
        assert!(
            format!("{err:#}").contains("no surviving shard"),
            "{err:#}"
        );
    }

    #[test]
    fn atomic_save_replaces_without_a_torn_read() {
        let dir = crate::util::tempdir().unwrap();
        let ds = SyntheticSpec::new(Family::Majority { informative: 2 }, 60, 4, 5).generate();
        write_shards(
            &ds,
            &TopologyParams {
                num_splitters: Some(2),
                ..Default::default()
            },
            dir.path(),
            &ShardOptions::default(),
            IoStats::new(),
        )
        .unwrap();
        let path = dir.path().join(ClusterManifest::FILE);
        let mut m = ClusterManifest::load(&path).unwrap();
        m.version = 41;
        save_manifest_atomic(&m, &path).unwrap();
        assert_eq!(ClusterManifest::load(&path).unwrap().version, 41);
        assert!(!path.with_extension("json.tmp").exists(), "tmp cleaned up");
    }

    #[test]
    fn bind_addr_and_host_helpers() {
        assert_eq!(bind_addr("127.0.0.2"), "127.0.0.2:0");
        assert_eq!(bind_addr("10.0.0.1:7000"), "10.0.0.1:7000");
        assert_eq!(host_of("127.0.0.1:4242"), "127.0.0.1");
    }
}
