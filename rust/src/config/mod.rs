//! Configuration system: every training/topology knob in one struct,
//! loadable from a JSON config file with CLI overrides (see `main.rs`).

use crate::rng::{BaggingMode, FeatureSampling};
use crate::util::Json;
use crate::splits::ScoreKind;
use std::path::Path;

/// Hyperparameters of the forest itself (paper §4/§5 defaults).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ForestParams {
    /// Number of trees `T`.
    pub num_trees: usize,
    /// User-chosen maximum depth `d` (paper §5 uses 20; `u32::MAX` for
    /// unbounded as in §4).
    pub max_depth: u32,
    /// Minimum bagged record weight for a leaf to remain open (paper's
    /// "minimum number of records in a leaf", ρ).
    pub min_records: u64,
    /// Candidate features per node `m'`; `None` = `⌈√m⌉` (the paper's
    /// default everywhere).
    pub num_candidate_features: Option<usize>,
    /// Per-node (classical RF) vs per-depth (USB, §3.2) vs all features.
    pub feature_sampling: FeatureSampling,
    /// Record bagging mode (§2.2).
    pub bagging: BaggingMode,
    /// Split quality measure.
    pub score_kind: ScoreKind,
    /// Forest seed — drives bagging, feature sampling, everything.
    pub seed: u64,
}

impl Default for ForestParams {
    fn default() -> Self {
        Self {
            num_trees: 10,
            max_depth: 20,
            min_records: 1,
            num_candidate_features: None,
            feature_sampling: FeatureSampling::PerNode,
            bagging: BaggingMode::Poisson,
            score_kind: ScoreKind::Gini,
            seed: 0x0DF0_1234,
        }
    }
}

impl ForestParams {
    /// Resolve `m'` for a dataset with `m` features.
    pub fn candidates_for(&self, num_features: usize) -> usize {
        self.num_candidate_features
            .unwrap_or_else(|| (num_features as f64).sqrt().ceil() as usize)
            .clamp(1, num_features)
    }

    /// Should a fresh leaf at `depth` with these bagged class counts
    /// remain open (splittable)? Shared by the distributed builder and
    /// every baseline so leaf-closing decisions are identical.
    pub fn child_open(&self, counts: &[u64], depth: u32) -> bool {
        let total: u64 = counts.iter().sum();
        depth < self.max_depth
            && total >= self.min_records
            && counts.iter().filter(|&&c| c > 0).count() >= 2
    }

    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.num_trees > 0, "num_trees must be positive");
        anyhow::ensure!(self.min_records >= 1, "min_records must be >= 1");
        if let Some(mp) = self.num_candidate_features {
            anyhow::ensure!(mp > 0, "num_candidate_features must be positive");
        }
        Ok(())
    }
}

/// SPRINT-style pruning of records in closed leaves (paper §3: "we can
/// implement a rule for switching to Sprint's pruning mode").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PruneMode {
    /// Never prune (the paper's experimental configuration — on Leo the
    /// trigger never fires anyway since 96.9% of records stay open).
    Never,
    /// Prune when the closed-record fraction exceeds `threshold`.
    Adaptive { threshold: f64 },
}

impl Default for PruneMode {
    fn default() -> Self {
        PruneMode::Never
    }
}

/// Worker topology (paper §2: splitters, tree builders, manager).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TopologyParams {
    /// Number of splitter workers `w`; `None` = one per column (the
    /// paper's Fig 1/2 setting: "the number of workers is equal to the
    /// dimension").
    pub num_splitters: Option<usize>,
    /// Feature replication factor `d` (§3.2): each column is owned by
    /// `d` splitters. 1 = no redundancy.
    pub redundancy: usize,
    /// Number of tree builders driven concurrently by the manager.
    pub tree_builders: usize,
    /// Artificial per-message network latency in microseconds (0 = off);
    /// DRF is "relatively insensitive to the latency" (§2) — this knob
    /// lets the benches demonstrate that.
    pub latency_us: u64,
}

impl Default for TopologyParams {
    fn default() -> Self {
        Self {
            num_splitters: None,
            redundancy: 1,
            tree_builders: 2,
            latency_us: 0,
        }
    }
}

impl TopologyParams {
    pub fn splitters_for(&self, num_features: usize) -> usize {
        self.num_splitters.unwrap_or(num_features).max(1)
    }

    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.redundancy >= 1, "redundancy must be >= 1");
        anyhow::ensure!(self.tree_builders >= 1, "need at least one tree builder");
        if let Some(w) = self.num_splitters {
            anyhow::ensure!(w >= 1, "need at least one splitter");
        }
        Ok(())
    }
}

/// Which split-scoring backend splitters use for numerical columns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScorerBackend {
    /// Exact scalar Rust implementation (default; the oracle).
    Native,
    /// Batched scoring through the AOT XLA/Pallas artifact.
    Xla,
}

impl Default for ScorerBackend {
    fn default() -> Self {
        ScorerBackend::Native
    }
}

/// Where splitters keep their column shards (which
/// [`crate::data::store::ColumnStore`] backend the manager builds).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StorageMode {
    /// Shards in RAM (fast path; the paper's "small and moderate size"
    /// configuration).
    Memory,
    /// Shards on disk as monolithic DRFC v1 files, re-read sequentially
    /// every pass (the paper's §5 configuration: "all experiments have
    /// been run with the datasets remaining on drive").
    Disk,
    /// Shards on disk in the chunked DRFC v2 layout (per-chunk record
    /// counts in the header, so passes can be resumed/limited without
    /// reading the tail). Trees are bit-identical to the other modes.
    DiskV2,
    /// Shards on disk as chunked DRFC v2 files, memory-mapped once:
    /// scans borrow chunk slices straight from the mapping (zero
    /// syscalls and zero copies after the first-touch pass; buffered
    /// fallback on non-unix). Trees are bit-identical to the other
    /// modes.
    Mmap,
    /// Shards on an object store (`drf objstore`), scanned by
    /// chunk-aligned byte-range reads over the wire
    /// ([`crate::data::remote::RemoteStore`]): retried with bounded
    /// backoff, resumable at chunk boundaries, optionally prefetched by
    /// a background fetcher (`prefetch_chunks`). With
    /// `TrainConfig::object_store` unset the manager self-hosts a
    /// loopback objstore over its own spilled shards (the
    /// self-contained mode tests and benches use). Trees are
    /// bit-identical to the other modes.
    Remote,
}

impl Default for StorageMode {
    fn default() -> Self {
        StorageMode::Memory
    }
}

/// How splitters search for the best split of each (leaf, column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SplitSearch {
    /// Exhaustive scan over every candidate threshold/subset — the
    /// paper's exact algorithm and the default everywhere.
    Exact,
    /// MABSplit-style successive elimination (arXiv 2212.07473): a
    /// deterministic strided sample pass scores the candidate columns
    /// per leaf, columns whose optimistic bound cannot beat the sampled
    /// leader are eliminated, and only the survivors get the exact
    /// final scan. Explicitly approximate — trees may differ from the
    /// exact ones (the ablation bench quantifies the AUC/time trade).
    Mab,
}

impl Default for SplitSearch {
    fn default() -> Self {
        SplitSearch::Exact
    }
}

impl SplitSearch {
    pub fn as_str(&self) -> &'static str {
        match self {
            SplitSearch::Exact => "exact",
            SplitSearch::Mab => "mab",
        }
    }

    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "exact" => Ok(SplitSearch::Exact),
            "mab" => Ok(SplitSearch::Mab),
            other => anyhow::bail!("unknown split search '{other}' (exact|mab)"),
        }
    }
}

/// Worker execution engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// In-process calls (deterministic, minimal overhead; network bytes
    /// are fully accounted either way).
    Direct,
    /// One OS thread per splitter behind request channels; tree builders
    /// run concurrently.
    Threaded,
    /// Splitters served over localhost TCP sockets with the binary wire
    /// codec — the fully literal distributed mode (still spawned by
    /// the leader process).
    Tcp,
    /// Remote `drf worker` processes located by a cluster manifest
    /// (`drf shard` output): the leader spawns nothing, connects to the
    /// fleet, validates it via the Hello handshake, and recovers
    /// restarted workers by replaying the level-update log.
    Cluster,
}

impl Default for Engine {
    fn default() -> Self {
        Engine::Direct
    }
}

/// Top-level training configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    pub forest: ForestParams,
    pub topology: TopologyParams,
    pub prune: PruneMode,
    pub scorer: ScorerBackend,
    pub storage: StorageMode,
    pub engine: Engine,
    /// Concurrent column scans per splitter: a splitter owning `k`
    /// columns scans up to this many of them at once on a scoped
    /// worker pool. Purely a wall-clock knob — trees and `IoStats`
    /// accounting are identical for any value.
    pub scan_threads: usize,
    /// Disk-scan prefetch depth: how many chunks a background reader
    /// may decode ahead of the scan visitor (applies to the `Disk` /
    /// `DiskV2` storage modes; 0 = synchronous scans). Chunks are
    /// still delivered strictly in order, so this — like
    /// `scan_threads` — never changes a tree or a completed pass's
    /// accounting, only wall clock.
    pub prefetch_chunks: usize,
    /// Object-store address (`host:port`) for
    /// [`StorageMode::Remote`]: the `drf objstore` serving the
    /// dataset's column files (`--object-store HOST:PORT`). `None` with
    /// remote storage makes the manager spill + self-host a loopback
    /// objstore for the run.
    pub object_store: Option<String>,
    /// Directory holding AOT artifacts (for `ScorerBackend::Xla`).
    pub artifacts_dir: Option<std::path::PathBuf>,
    /// Cluster manifest (`cluster.json` from `drf shard`); required by
    /// [`Engine::Cluster`], ignored otherwise.
    pub cluster_manifest: Option<std::path::PathBuf>,
    /// Worker addresses (`host:port`, one per shard in shard order).
    /// Empty = use the addresses recorded in the cluster manifest.
    pub cluster_workers: Vec<String>,
    /// Serve `GET /metrics` (the [`crate::telemetry`] registry) on this
    /// address for the duration of the run (`--metrics-addr HOST:PORT`;
    /// `HOST:0` picks an ephemeral port). `None` = no listener.
    pub metrics_addr: Option<String>,
    /// Stream phase-tracing span events as JSONL to this file
    /// (`--trace-out PATH`). `None` = tracing off.
    pub trace_out: Option<std::path::PathBuf>,
    /// Depth-next switch threshold (`--depth-next-rows`): once an open
    /// leaf's bagged row weight drops to this value or below, its rows
    /// are materialized from the splitters into a node-local column set
    /// and the whole subtree is grown depth-first in memory — no
    /// further full-dataset passes for that subtree. Bit-identical to
    /// pure breadth-first growth. `0` disables the hybrid schedule;
    /// the default is one storage chunk
    /// ([`crate::data::disk::DEFAULT_CHUNK_ROWS`]), the unit the
    /// streaming backends already buffer.
    pub depth_next_rows: u64,
    /// Split-search strategy (`--split-search exact|mab`). `Exact` is
    /// the paper's algorithm and the default; `Mab` is the opt-in
    /// successive-elimination approximation.
    pub split_search: SplitSearch,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            forest: ForestParams::default(),
            topology: TopologyParams::default(),
            prune: PruneMode::default(),
            scorer: ScorerBackend::default(),
            storage: StorageMode::default(),
            engine: Engine::default(),
            scan_threads: 1,
            prefetch_chunks: 0,
            object_store: None,
            artifacts_dir: None,
            cluster_manifest: None,
            cluster_workers: Vec::new(),
            metrics_addr: None,
            trace_out: None,
            depth_next_rows: crate::data::disk::DEFAULT_CHUNK_ROWS as u64,
            split_search: SplitSearch::default(),
        }
    }
}

impl TrainConfig {
    pub fn validate(&self) -> crate::Result<()> {
        self.forest.validate()?;
        self.topology.validate()?;
        anyhow::ensure!(self.scan_threads >= 1, "scan_threads must be >= 1");
        if let PruneMode::Adaptive { threshold } = self.prune {
            anyhow::ensure!(
                (0.0..=1.0).contains(&threshold),
                "prune threshold must be in [0,1]"
            );
        }
        if self.engine == Engine::Cluster {
            anyhow::ensure!(
                self.cluster_manifest.is_some(),
                "--engine cluster needs a cluster manifest (--manifest cluster.json)"
            );
        }
        Ok(())
    }

    /// Serialize to JSON (the on-disk config format).
    pub fn to_json(&self) -> Json {
        let mut f = Json::object();
        f.set("num_trees", Json::from_usize(self.forest.num_trees))
            .set("max_depth", Json::from_u64(self.forest.max_depth as u64))
            .set("min_records", Json::from_u64(self.forest.min_records))
            .set(
                "num_candidate_features",
                match self.forest.num_candidate_features {
                    Some(v) => Json::from_usize(v),
                    None => Json::Null,
                },
            )
            .set(
                "feature_sampling",
                Json::Str(self.forest.feature_sampling.as_str().into()),
            )
            .set("bagging", Json::Str(self.forest.bagging.as_str().into()))
            .set("score_kind", Json::Str(self.forest.score_kind.as_str().into()))
            .set("seed", Json::from_u64(self.forest.seed));
        let mut t = Json::object();
        t.set(
            "num_splitters",
            match self.topology.num_splitters {
                Some(v) => Json::from_usize(v),
                None => Json::Null,
            },
        )
        .set("redundancy", Json::from_usize(self.topology.redundancy))
        .set("tree_builders", Json::from_usize(self.topology.tree_builders))
        .set("latency_us", Json::from_u64(self.topology.latency_us));
        let mut o = Json::object();
        o.set("forest", f)
            .set("topology", t)
            .set(
                "prune_threshold",
                match self.prune {
                    PruneMode::Never => Json::Null,
                    PruneMode::Adaptive { threshold } => Json::Num(threshold),
                },
            )
            .set(
                "scorer",
                Json::Str(
                    match self.scorer {
                        ScorerBackend::Native => "native",
                        ScorerBackend::Xla => "xla",
                    }
                    .into(),
                ),
            )
            .set(
                "storage",
                Json::Str(
                    match self.storage {
                        StorageMode::Memory => "memory",
                        StorageMode::Disk => "disk",
                        StorageMode::DiskV2 => "disk_v2",
                        StorageMode::Mmap => "mmap",
                        StorageMode::Remote => "remote",
                    }
                    .into(),
                ),
            )
            .set("scan_threads", Json::from_usize(self.scan_threads))
            .set("prefetch_chunks", Json::from_usize(self.prefetch_chunks))
            .set(
                "object_store",
                match &self.object_store {
                    Some(a) => Json::Str(a.clone()),
                    None => Json::Null,
                },
            )
            .set(
                "engine",
                Json::Str(
                    match self.engine {
                        Engine::Direct => "direct",
                        Engine::Threaded => "threaded",
                        Engine::Tcp => "tcp",
                        Engine::Cluster => "cluster",
                    }
                    .into(),
                ),
            )
            .set(
                "artifacts_dir",
                match &self.artifacts_dir {
                    Some(p) => Json::Str(p.display().to_string()),
                    None => Json::Null,
                },
            )
            .set(
                "cluster_manifest",
                match &self.cluster_manifest {
                    Some(p) => Json::Str(p.display().to_string()),
                    None => Json::Null,
                },
            )
            .set(
                "cluster_workers",
                Json::Arr(
                    self.cluster_workers
                        .iter()
                        .map(|w| Json::Str(w.clone()))
                        .collect(),
                ),
            )
            .set(
                "metrics_addr",
                match &self.metrics_addr {
                    Some(a) => Json::Str(a.clone()),
                    None => Json::Null,
                },
            )
            .set(
                "trace_out",
                match &self.trace_out {
                    Some(p) => Json::Str(p.display().to_string()),
                    None => Json::Null,
                },
            )
            .set("depth_next_rows", Json::from_u64(self.depth_next_rows))
            .set("split_search", Json::Str(self.split_search.as_str().into()));
        o
    }

    /// Parse from JSON text. Missing keys fall back to defaults;
    /// **unknown keys are rejected** — a leader and a worker built from
    /// slightly different versions must fail loudly instead of silently
    /// dropping a typo'd or not-yet-understood flag (a misspelled
    /// `depth_next_rows` that parsed as "use the default" would train a
    /// different schedule than the operator asked for).
    pub fn from_json(text: &str) -> crate::Result<Self> {
        let v = Json::parse(text)?;
        reject_unknown_keys(
            &v,
            "config",
            &[
                "forest",
                "topology",
                "prune_threshold",
                "scorer",
                "storage",
                "scan_threads",
                "prefetch_chunks",
                "object_store",
                "engine",
                "artifacts_dir",
                "cluster_manifest",
                "cluster_workers",
                "metrics_addr",
                "trace_out",
                "depth_next_rows",
                "split_search",
            ],
        )?;
        let mut cfg = TrainConfig::default();
        if let Some(f) = v.get_opt("forest") {
            reject_unknown_keys(
                f,
                "config.forest",
                &[
                    "num_trees",
                    "max_depth",
                    "min_records",
                    "num_candidate_features",
                    "feature_sampling",
                    "bagging",
                    "score_kind",
                    "seed",
                ],
            )?;
            if let Some(x) = f.get_opt("num_trees") {
                cfg.forest.num_trees = x.as_usize()?;
            }
            if let Some(x) = f.get_opt("max_depth") {
                cfg.forest.max_depth = x.as_u32()?;
            }
            if let Some(x) = f.get_opt("min_records") {
                cfg.forest.min_records = x.as_u64()?;
            }
            if let Some(x) = f.get_opt("num_candidate_features") {
                cfg.forest.num_candidate_features = match x {
                    Json::Null => None,
                    other => Some(other.as_usize()?),
                };
            }
            if let Some(x) = f.get_opt("feature_sampling") {
                cfg.forest.feature_sampling = FeatureSampling::parse(x.as_str()?)?;
            }
            if let Some(x) = f.get_opt("bagging") {
                cfg.forest.bagging = BaggingMode::parse(x.as_str()?)?;
            }
            if let Some(x) = f.get_opt("score_kind") {
                cfg.forest.score_kind = ScoreKind::parse(x.as_str()?)?;
            }
            if let Some(x) = f.get_opt("seed") {
                cfg.forest.seed = x.as_u64()?;
            }
        }
        if let Some(t) = v.get_opt("topology") {
            reject_unknown_keys(
                t,
                "config.topology",
                &["num_splitters", "redundancy", "tree_builders", "latency_us"],
            )?;
            if let Some(x) = t.get_opt("num_splitters") {
                cfg.topology.num_splitters = match x {
                    Json::Null => None,
                    other => Some(other.as_usize()?),
                };
            }
            if let Some(x) = t.get_opt("redundancy") {
                cfg.topology.redundancy = x.as_usize()?;
            }
            if let Some(x) = t.get_opt("tree_builders") {
                cfg.topology.tree_builders = x.as_usize()?;
            }
            if let Some(x) = t.get_opt("latency_us") {
                cfg.topology.latency_us = x.as_u64()?;
            }
        }
        if let Some(x) = v.get_opt("prune_threshold") {
            cfg.prune = match x {
                Json::Null => PruneMode::Never,
                other => PruneMode::Adaptive {
                    threshold: other.as_f64()?,
                },
            };
        }
        if let Some(x) = v.get_opt("scorer") {
            cfg.scorer = match x.as_str()? {
                "native" => ScorerBackend::Native,
                "xla" => ScorerBackend::Xla,
                s => anyhow::bail!("unknown scorer backend '{s}'"),
            };
        }
        if let Some(x) = v.get_opt("storage") {
            cfg.storage = match x.as_str()? {
                "memory" => StorageMode::Memory,
                "disk" => StorageMode::Disk,
                "disk_v2" => StorageMode::DiskV2,
                "mmap" => StorageMode::Mmap,
                "remote" => StorageMode::Remote,
                s => anyhow::bail!("unknown storage mode '{s}'"),
            };
        }
        if let Some(x) = v.get_opt("scan_threads") {
            cfg.scan_threads = x.as_usize()?;
        }
        if let Some(x) = v.get_opt("prefetch_chunks") {
            cfg.prefetch_chunks = x.as_usize()?;
        }
        if let Some(x) = v.get_opt("object_store") {
            cfg.object_store = match x {
                Json::Null => None,
                other => Some(other.as_str()?.to_string()),
            };
        }
        if let Some(x) = v.get_opt("engine") {
            cfg.engine = match x.as_str()? {
                "direct" => Engine::Direct,
                "threaded" => Engine::Threaded,
                "tcp" => Engine::Tcp,
                "cluster" => Engine::Cluster,
                s => anyhow::bail!("unknown engine '{s}'"),
            };
        }
        if let Some(x) = v.get_opt("artifacts_dir") {
            cfg.artifacts_dir = match x {
                Json::Null => None,
                other => Some(std::path::PathBuf::from(other.as_str()?)),
            };
        }
        if let Some(x) = v.get_opt("cluster_manifest") {
            cfg.cluster_manifest = match x {
                Json::Null => None,
                other => Some(std::path::PathBuf::from(other.as_str()?)),
            };
        }
        if let Some(x) = v.get_opt("cluster_workers") {
            cfg.cluster_workers = x
                .as_arr()?
                .iter()
                .map(|w| Ok(w.as_str()?.to_string()))
                .collect::<crate::Result<Vec<_>>>()?;
        }
        if let Some(x) = v.get_opt("metrics_addr") {
            cfg.metrics_addr = match x {
                Json::Null => None,
                other => Some(other.as_str()?.to_string()),
            };
        }
        if let Some(x) = v.get_opt("trace_out") {
            cfg.trace_out = match x {
                Json::Null => None,
                other => Some(std::path::PathBuf::from(other.as_str()?)),
            };
        }
        if let Some(x) = v.get_opt("depth_next_rows") {
            cfg.depth_next_rows = x.as_u64()?;
        }
        if let Some(x) = v.get_opt("split_search") {
            cfg.split_search = SplitSearch::parse(x.as_str()?)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &Path) -> crate::Result<Self> {
        Self::from_json(&std::fs::read_to_string(path)?)
    }
}

/// Ensure an object's keys are a subset of `allowed` (see
/// [`TrainConfig::from_json`] for why unknown keys are a hard error).
/// Non-object values pass through — the per-key accessors report their
/// own type errors.
fn reject_unknown_keys(v: &Json, what: &str, allowed: &[&str]) -> crate::Result<()> {
    if let Json::Obj(map) = v {
        for key in map.keys() {
            anyhow::ensure!(
                allowed.contains(&key.as_str()),
                "{what}: unknown key '{key}' (allowed: {})",
                allowed.join(", ")
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        let cfg = TrainConfig::default();
        cfg.validate().unwrap();
        assert_eq!(cfg.forest.num_trees, 10);
        assert_eq!(cfg.scorer, ScorerBackend::Native);
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = TrainConfig::default();
        cfg.forest.num_trees = 3;
        cfg.forest.num_candidate_features = Some(4);
        cfg.topology.redundancy = 2;
        cfg.prune = PruneMode::Adaptive { threshold: 0.5 };
        cfg.storage = StorageMode::Disk;
        cfg.engine = Engine::Threaded;
        cfg.scorer = ScorerBackend::Xla;
        cfg.scan_threads = 6;
        cfg.artifacts_dir = Some(std::path::PathBuf::from("artifacts"));
        let s = cfg.to_json().to_string();
        let back = TrainConfig::from_json(&s).unwrap();
        assert_eq!(cfg, back);
        // The v2 storage mode roundtrips too.
        cfg.storage = StorageMode::DiskV2;
        let back = TrainConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(cfg, back);
        // And the mmap mode + prefetch depth.
        cfg.storage = StorageMode::Mmap;
        cfg.prefetch_chunks = 3;
        let back = TrainConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(cfg, back);
        // And the remote mode, with and without an objstore address.
        cfg.storage = StorageMode::Remote;
        let back = TrainConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(cfg, back);
        cfg.object_store = Some("10.0.0.9:7979".into());
        let back = TrainConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(cfg, back);
        cfg.object_store = None;
        // And the cluster engine with its manifest + worker list.
        cfg.engine = Engine::Cluster;
        cfg.cluster_manifest = Some(std::path::PathBuf::from("/tmp/cluster.json"));
        cfg.cluster_workers = vec!["10.0.0.1:7777".into(), "10.0.0.2:7777".into()];
        let back = TrainConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(cfg, back);
        // Telemetry knobs roundtrip too (both set and unset).
        cfg.metrics_addr = Some("127.0.0.1:9105".into());
        cfg.trace_out = Some(std::path::PathBuf::from("/tmp/trace.jsonl"));
        let back = TrainConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(cfg, back);
        // The depth-next budget and split-search knobs roundtrip,
        // including the disabled (0) budget.
        cfg.depth_next_rows = 0;
        cfg.split_search = SplitSearch::Mab;
        let back = TrainConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(cfg, back);
        cfg.depth_next_rows = 4096;
        let back = TrainConfig::from_json(&cfg.to_json().to_string()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn unknown_keys_rejected() {
        // A typo'd flag must not silently train a different config: the
        // leader/worker round-trip through cluster.json has to fail.
        for bad in [
            "{\"depth_next_rowz\": 100}",
            "{\"forest\": {\"num_treez\": 3}}",
            "{\"topology\": {\"splitters\": 2}}",
        ] {
            let err = TrainConfig::from_json(bad).unwrap_err();
            assert!(
                format!("{err:#}").contains("unknown key"),
                "{bad}: {err:#}"
            );
        }
    }

    #[test]
    fn cluster_engine_requires_manifest() {
        let mut cfg = TrainConfig::default();
        cfg.engine = Engine::Cluster;
        assert!(cfg.validate().is_err());
        cfg.cluster_manifest = Some(std::path::PathBuf::from("cluster.json"));
        cfg.validate().unwrap();
    }

    #[test]
    fn partial_json_uses_defaults() {
        let cfg = TrainConfig::from_json("{\"forest\": {\"num_trees\": 7}}").unwrap();
        assert_eq!(cfg.forest.num_trees, 7);
        assert_eq!(cfg.forest.max_depth, 20);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(TrainConfig::from_json("{\"forest\": {\"num_trees\": 0}}").is_err());
        assert!(TrainConfig::from_json("{\"scorer\": \"gpu\"}").is_err());
        assert!(TrainConfig::from_json("{\"storage\": \"tape\"}").is_err());
        assert!(TrainConfig::from_json("{\"scan_threads\": 0}").is_err());
        assert!(TrainConfig::from_json("{\"split_search\": \"genetic\"}").is_err());
        let mut cfg = TrainConfig::default();
        cfg.prune = PruneMode::Adaptive { threshold: 1.5 };
        assert!(cfg.validate().is_err());
        cfg.prune = PruneMode::Never;
        cfg.topology.redundancy = 0;
        assert!(cfg.validate().is_err());
        cfg.topology.redundancy = 1;
        cfg.scan_threads = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn sqrt_candidate_default() {
        let p = ForestParams::default();
        assert_eq!(p.candidates_for(82), 10);
        assert_eq!(p.candidates_for(18), 5);
        assert_eq!(p.candidates_for(1), 1);
        let p2 = ForestParams {
            num_candidate_features: Some(50),
            ..p
        };
        assert_eq!(p2.candidates_for(10), 10, "clamped to m");
    }
}
