//! `drf` — the DRF leader/worker binary.
//!
//! Subcommands:
//!
//! * `train`     — train a forest on a synthetic family or the Leo-like
//!                 dataset and save it as JSON (plus a training report);
//! * `generate`  — write a dataset directory (schema + presorted
//!                 columns) for later `--data` runs;
//! * `shard`     — cut a dataset into per-splitter shard packs plus a
//!                 cluster manifest (`drf::cluster`);
//! * `objstore`  — serve byte ranges of a dataset/shard directory to
//!                 remote-storage trainers and workers (`drf::data::objserve`);
//! * `worker`    — serve one shard pack as a standalone splitter
//!                 process (the leader's Hello handshake configures it);
//!                 with `--object-store` the pack itself is fetched
//!                 remotely and never downloaded in full;
//! * `supervise` — autonomous cluster control plane: boot the fleet
//!                 (optionally objstore replica sets), health-check
//!                 every process, restart/reschedule the dead, and
//!                 re-shard workers out of a live run (`--drain`, or
//!                 `drain N` on the control channel);
//! * `evaluate`  — score a saved forest on a freshly generated test set;
//! * `importance`— print MDI feature importances of a saved forest;
//! * `serve`     — serve a saved forest over TCP (flattened engine,
//!                 hot reload);
//! * `predict`   — score a dataset against a running server (`--addr`)
//!                 or locally against a saved model (`--model`);
//! * `metrics`   — scrape the `/metrics` endpoint of a running drf
//!                 process (`--metrics-addr`) and print it, optionally
//!                 on a loop (`--watch`, with per-second rates);
//! * `trace`     — merge per-process `--trace-out` files into one
//!                 clock-aligned Chrome trace JSON (`merge`) or print
//!                 the per-round straggler report (`report`);
//! * `fuzz`      — deterministic mutational fuzzing of every untrusted
//!                 decoder (wire codecs, manifests, DRFC headers) with
//!                 seed/trace reproduction and repro minimization
//!                 (`drf::fuzz`);
//! * `info`      — runtime/platform info (PJRT client, artifacts).
//!
//! Examples:
//!
//! ```text
//! drf train --family xor --informative 3 --rows 10000 --features 6 \
//!     --trees 10 --depth 12 --out /tmp/forest.json
//! drf train --family leo --rows 100000 --trees 3 --depth 20 \
//!     --storage disk --report /tmp/report.json
//! drf generate --family leo --rows 100000 --chunk-rows 65536 --out-dir /tmp/leo
//! drf objstore --dir /tmp/leo --addr 0.0.0.0:9000
//! drf train --family leo --rows 100000 --trees 3 \
//!     --storage remote --object-store 127.0.0.1:9000
//! drf shard --family leo --rows 100000 --splitters 4 --out-dir /tmp/shards
//! drf worker --shard /tmp/shards/shard_0 --addr 0.0.0.0:7001
//! drf objstore --dir /tmp/shards --addr 0.0.0.0:9000
//! drf worker --shard shard_0 --object-store 127.0.0.1:9000 --addr 0.0.0.0:7001
//! drf train --engine cluster --manifest /tmp/shards/cluster.json \
//!     --workers host0:7001,host1:7001,host2:7001,host3:7001 \
//!     --family leo --rows 100000 --trees 3
//! drf evaluate --model /tmp/forest.json --family xor --informative 3 \
//!     --rows 5000 --features 6 --seed 99
//! drf serve --model /tmp/forest.json --addr 127.0.0.1:7878
//! drf predict --addr 127.0.0.1:7878 --family xor --informative 3 \
//!     --rows 5000 --features 6 --seed 99
//! ```

use anyhow::{bail, Context, Result};
use drf::config::{Engine, ScorerBackend, StorageMode, TrainConfig};
use drf::data::synthetic::{Family, LeoLikeSpec, SyntheticSpec};
use drf::data::Dataset;
use drf::forest::importance::{mdi_importance, rank_features};
use drf::forest::RandomForest;
use drf::metrics::auc;
use drf::rng::{BaggingMode, FeatureSampling};
use drf::util::cli::Args;
use drf::util::Json;

const TRAIN_FLAGS: &[&str] = &[
    "csv",
    "label-column",
    "data",
    "family",
    "informative",
    "rows",
    "features",
    "seed",
    "trees",
    "depth",
    "min-records",
    "candidates",
    "sampling",
    "bagging",
    "splitters",
    "redundancy",
    "builders",
    "latency-us",
    "storage",
    "depth-next-rows",
    "split-search",
    "scan-threads",
    "prefetch-chunks",
    "object-store",
    "engine",
    "scorer",
    "artifacts-dir",
    "manifest",
    "workers",
    "config",
    "out",
    "report",
    "metrics-addr",
    "trace-out",
];

const WORKER_FLAGS: &[&str] = &[
    "shard",
    "addr",
    "scan-threads",
    "prefetch-chunks",
    "object-store",
    "metrics-addr",
    "trace-out",
    "!preload",
    "!no-verify",
];

const OBJSTORE_FLAGS: &[&str] = &["dir", "addr", "fail-after", "metrics-addr", "trace-out"];

const SUPERVISE_FLAGS: &[&str] = &[
    "dir",
    "drain",
    "spare-hosts",
    "control-addr",
    "interval-ms",
    "fail-threshold",
    "objstore-replicas",
    "log",
    "trace-dir",
    "scan-threads",
    "prefetch-chunks",
    "metrics-addr",
    "trace-out",
    "!preload",
    "!no-verify",
];

const SERVE_FLAGS: &[&str] = &["model", "addr", "metrics-addr", "trace-out"];

const METRICS_FLAGS: &[&str] = &["interval-ms", "!watch"];

const TRACE_FLAGS: &[&str] = &["out"];

const FUZZ_FLAGS: &[&str] = &["target", "seed", "iters", "corpus", "repro-out", "!minimize"];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let command = argv.first().map(|s| s.as_str()).unwrap_or("help");
    match command {
        "train" => cmd_train(&argv[1..]),
        "generate" => cmd_generate(&argv[1..]),
        "shard" => cmd_shard(&argv[1..]),
        "objstore" => cmd_objstore(&argv[1..]),
        "worker" => cmd_worker(&argv[1..]),
        "supervise" => cmd_supervise(&argv[1..]),
        "evaluate" => cmd_evaluate(&argv[1..]),
        "importance" => cmd_importance(&argv[1..]),
        "serve" => cmd_serve(&argv[1..]),
        "predict" => cmd_predict(&argv[1..]),
        "metrics" => cmd_metrics(&argv[1..]),
        "trace" => cmd_trace(&argv[1..]),
        "fuzz" => cmd_fuzz(&argv[1..]),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `drf help`)"),
    }
}

const HELP: &str = "\
drf — exact distributed Random Forest (DRF)

USAGE:
  drf train [--family xor|majority|needle|linear|leo] [--rows N]
            [--features M] [--informative K] [--seed S]
            [--trees T] [--depth D] [--min-records R] [--candidates M']
            [--sampling per_node|per_depth|all] [--bagging poisson|none]
            [--splitters W] [--redundancy D] [--builders B]
            [--latency-us U] [--storage memory|disk|disk_v2|mmap|remote]
            [--depth-next-rows N] [--split-search exact|mab]
            [--object-store HOST:PORT]
            [--scan-threads K] [--prefetch-chunks P]
            [--engine direct|threaded|tcp|cluster]
            [--manifest cluster.json] [--workers ADDR,ADDR,...]
            [--scorer native|xla]
            [--artifacts-dir DIR] [--config cfg.json]
            [--out forest.json] [--report report.json]
            [--csv file.csv [--label-column NAME]] [--data dataset-dir]
            [--metrics-addr HOST:PORT] [--trace-out trace.jsonl]
  drf generate [--family ...] [--rows N] [--seed S] [--chunk-rows C]
               --out-dir DIR
  drf shard [--family ...|--csv ...|--data DIR] [--rows N] [--seed S]
            [--splitters W] [--redundancy D] [--chunk-rows C]
            [--replicas R] [--workers ADDR,ADDR,...] --out-dir DIR
  drf objstore --dir DIR [--addr HOST:PORT] [--fail-after N]
               [--metrics-addr HOST:PORT] [--trace-out trace.jsonl]
  drf worker --shard SHARD_DIR [--addr HOST:PORT] [--scan-threads K]
             [--prefetch-chunks P] [--preload] [--no-verify]
             [--object-store HOST:PORT] [--metrics-addr HOST:PORT]
             [--trace-out trace.jsonl]
  drf supervise --dir SHARD_DIR [--objstore-replicas R]
                [--spare-hosts HOST,HOST,...] [--control-addr HOST:PORT]
                [--interval-ms MS] [--fail-threshold N]
                [--log actions.jsonl] [--trace-dir DIR]
                [--scan-threads K] [--prefetch-chunks P] [--preload]
                [--no-verify] [--metrics-addr HOST:PORT]
                [--trace-out trace.jsonl]
  drf supervise --dir SHARD_DIR --drain I
  drf evaluate --model forest.json [--family ...|--csv ...|--data DIR]
  drf importance --model forest.json [--features M]
  drf serve --model forest.json [--addr HOST:PORT]
            [--metrics-addr HOST:PORT] [--trace-out trace.jsonl]
  drf predict (--addr HOST:PORT | --model forest.json)
              [--family ...|--csv ...|--data DIR] [--show N]
  drf metrics ADDR [--watch] [--interval-ms MS]
  drf trace merge FILE... --out trace.json
  drf trace report FILE...
  drf fuzz [--target all|NAME[,NAME...]] [--seed S] [--iters N]
           [--corpus DIR] [--minimize] [--repro-out DIR]
  drf info

Data sources (train/evaluate/shard/predict): --csv loads a CSV file
(schema inferred, label column by name); --data loads a dataset
directory written by `drf generate`; otherwise a synthetic family is
generated in memory.

Storage: `memory` holds shards in RAM; `disk`/`disk_v2` stream every
pass from DRFC files through bounded buffers (`--prefetch-chunks P`
lets a background reader decode P chunks ahead); `mmap` maps chunked
DRFC v2 files once and scans borrow slices straight from the mapping
(zero syscalls and copies after the first-touch pass); `remote` scans
by chunk-aligned byte-range reads against a `drf objstore`
(`--object-store HOST:PORT` serving a `drf generate` directory;
without it the trainer self-hosts a loopback objstore —
`--prefetch-chunks` pipelines the range reads, transient fetch
failures retry with backoff and resume at chunk boundaries). All
modes produce bit-identical forests.

Training schedule: trees grow breadth-first level by level; once an
open node's bagged row count drops to `--depth-next-rows N` (default
65536, the chunk size) the builder materializes that node's rows into
a compact in-memory column set and grows the whole subtree locally —
the deep tail of the tree stops paying per-level distributed scan
rounds. `--depth-next-rows 0` disables the switch (pure breadth-first).
Both schedules produce bit-identical forests. `--split-search mab`
replaces the exhaustive supersplit scan with a successive-elimination
sampled pass (MABSplit-style) that prunes hopeless candidate features
on row subsamples before one exact final scan over the survivors;
`exact` (the default) keeps the always-exhaustive scan. MAB changes
which candidates reach the final scan, so forests may differ from
`exact` — use it when wall-clock beats bit-reproducibility.

Object store: `drf objstore --dir DIR` serves byte ranges of the DRFC
files under DIR (a `drf generate` dataset directory or a `drf shard`
output tree) on `--addr` (default 127.0.0.1:0, ephemeral, printed on
the ready line). `--fail-after N` makes it exit right before the Nth
range read — crash-simulation for retry/resume tests and drills.

Cluster training: `drf shard` cuts the dataset into per-splitter shard
packs (presorted DRFC v2 columns + checksummed manifests) plus a
cluster.json deployment map; each pack is served by a `drf worker`
process (`--addr host:0` picks an ephemeral port and prints it;
`--preload` memory-maps the pack and serves it zero-copy, with
manifest checksums verified against the mapped bytes; `--no-verify`
skips the checksums in either mode — header validation still runs;
`--prefetch-chunks` applies to the streaming mode; with
`--object-store HOST:PORT` the worker fetches the pack — manifest,
labels, and every training scan — from a `drf objstore` serving the
shard tree, `--shard` naming the pack's directory under it, e.g.
shard_0, so the worker serves a shard it never downloaded in full);
`drf train --engine cluster --manifest cluster.json` connects to the
fleet (addresses from the manifest or --workers, comma-separated, in
shard order), validates it via the Hello handshake, and recovers
killed-and-restarted workers by replaying the level-update log — the
forest is bit-identical to --engine direct. `drf shard --replicas R`
additionally writes R byte-identical copies of every pack under
`replica_<r>/` subdirectories for externally managed replica sets.

Supervision: `drf supervise --dir SHARD_DIR` boots one worker per
pack (plus `--objstore-replicas R` objstore processes all serving the
shard tree — workers then stream their packs remotely and fail over
between replicas client-side), publishes every address in
cluster.json, and probes the fleet each `--interval-ms`: process exit,
the pre-handshake TimeSync RPC, and GET /healthz. A process dead for
`--fail-threshold` consecutive probes is restarted in place; one that
keeps crashing is rescheduled onto the `--spare-hosts` pool. Every
rewrite bumps the manifest version — a cluster leader re-reads
cluster.json between trees (and worker addresses mid-reconnect), so
failover and re-shards reach it without any new RPC, and the forest
stays bit-identical. `--control-addr` accepts one-line commands
(status | kill N | kill objstore [R] | drain N | quit) for operators
and chaos drills; `drain N` re-shards worker N's columns onto the
surviving fleet mid-run, and `drf supervise --dir D --drain I` does
the same offline. `--log` appends one JSON line per control-plane
action; `--trace-dir` gives every child its own `--trace-out` file.

Serving: `drf serve` compiles the model into the flattened inference
engine and answers Score/Classify/ModelInfo/Reload RPCs over a
length-prefixed binary protocol; `drf predict --addr` scores over TCP,
`drf predict --model` scores in-process.

Observability: every long-running process (train, objstore, worker,
supervise, serve) takes `--metrics-addr HOST:PORT` and exposes its metrics
registry — counters, gauges, and log2-bucketed histograms for every
training phase, cluster round, remote fetch, and serving RPC — as
Prometheus text on `GET /metrics` (port 0 picks an ephemeral port; the
bound address is printed on a `metrics on` ready line); `GET /healthz`
on the same port returns a JSON liveness document. `drf metrics ADDR`
scrapes and prints one snapshot; `--watch` re-scrapes every
`--interval-ms MS` (default 2000) and annotates every changed sample
with its per-second rate. `--trace-out trace.jsonl` (accepted by
train, worker, objstore, and serve) streams one JSON line per phase
span (tree builds, level scan/eval/update, splitter passes, objstore
reads) with microsecond timestamps, durations, and span/parent ids;
RPCs carry the caller's trace context so worker spans parent under the
leader's round spans, and connection handshakes measure peer clock
offsets. `drf trace merge` stitches the per-process files into one
clock-aligned Chrome trace-event JSON (load it at https://ui.perfetto.dev);
`drf trace report` prints the per-round critical path — slowest
worker, gap versus the median, dominant phase. Telemetry is
observation-only: forests are bit-identical with it on or off. See
docs/observability.md for the metric catalog and trace schema.

Fuzzing: `drf fuzz` runs the in-tree deterministic wire-protocol
fuzzer against every decoder that consumes untrusted bytes (frame
reader, coordinator/serving/objstore codecs, JSON, manifests, DRFC
headers — `--target all` or a comma-separated subset of the names
printed by a run). The whole run is a pure function of `--seed` and
the corpus: identical output across reruns, which is what the CI
fuzz-smoke job asserts. Failures print the exact case seed and
mutation trace, `--minimize` shrinks the failing frame, `--repro-out
DIR` writes it to disk, and `--corpus DIR` swaps in an alternative
seed-frame directory (default: the built-in encoder corpus, golden
copies in rust/tests/corpus/). See docs/fuzzing.md.
";

/// Build the dataset described by the common data flags.
fn dataset_from_args(args: &Args) -> Result<(Dataset, String)> {
    if let Some(path) = args.get("csv") {
        let opts = drf::data::csv::CsvOptions {
            label_column: args.get_string("label-column", "label"),
            ..Default::default()
        };
        let ds = drf::data::csv::load_csv(std::path::Path::new(path), &opts)?;
        return Ok((ds, format!("csv:{path}")));
    }
    if let Some(dir) = args.get("data") {
        let ds = drf::data::store::load_dataset(
            std::path::Path::new(dir),
            drf::data::io_stats::IoStats::new(),
        )?;
        return Ok((ds, format!("store:{dir}")));
    }
    let family = args.get_string("family", "majority");
    let rows = args.get_usize("rows", 10_000)?;
    let seed = args.get_u64("seed", 1)?;
    let informative = args.get_usize("informative", 3)?;
    let ds = match family.as_str() {
        "leo" => LeoLikeSpec::new(rows, seed).generate(),
        name => {
            let features = args.get_usize("features", informative + 3)?;
            let fam = match name {
                "xor" => Family::Xor { informative },
                "majority" => Family::Majority { informative },
                "needle" => Family::Needle { informative },
                "linear" => Family::LinearCont { informative },
                other => bail!("unknown family '{other}'"),
            };
            SyntheticSpec::new(fam, rows, features, seed).generate()
        }
    };
    Ok((ds, family))
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, TRAIN_FLAGS)?;
    let mut cfg = match args.get("config") {
        Some(path) => TrainConfig::load(std::path::Path::new(path))
            .with_context(|| format!("loading config {path}"))?,
        None => TrainConfig::default(),
    };
    // CLI overrides.
    cfg.forest.num_trees = args.get_usize("trees", cfg.forest.num_trees)?;
    cfg.forest.max_depth = args.get_u32("depth", cfg.forest.max_depth)?;
    cfg.forest.min_records = args.get_u64("min-records", cfg.forest.min_records)?;
    cfg.forest.seed = args.get_u64("seed", cfg.forest.seed)?;
    if let Some(v) = args.get("candidates") {
        cfg.forest.num_candidate_features = Some(v.parse()?);
    }
    if let Some(v) = args.get("sampling") {
        cfg.forest.feature_sampling = FeatureSampling::parse(v)?;
    }
    if let Some(v) = args.get("bagging") {
        cfg.forest.bagging = BaggingMode::parse(v)?;
    }
    if let Some(v) = args.get("splitters") {
        cfg.topology.num_splitters = Some(v.parse()?);
    }
    cfg.topology.redundancy = args.get_usize("redundancy", cfg.topology.redundancy)?;
    cfg.topology.tree_builders = args.get_usize("builders", cfg.topology.tree_builders)?;
    cfg.topology.latency_us = args.get_u64("latency-us", cfg.topology.latency_us)?;
    if let Some(v) = args.get("storage") {
        cfg.storage = match v {
            "memory" => StorageMode::Memory,
            "disk" => StorageMode::Disk,
            "disk_v2" => StorageMode::DiskV2,
            "mmap" => StorageMode::Mmap,
            "remote" => StorageMode::Remote,
            _ => bail!("storage must be memory|disk|disk_v2|mmap|remote"),
        };
    }
    cfg.depth_next_rows = args.get_u64("depth-next-rows", cfg.depth_next_rows)?;
    if let Some(v) = args.get("split-search") {
        cfg.split_search = drf::config::SplitSearch::parse(v)?;
    }
    cfg.scan_threads = args.get_usize("scan-threads", cfg.scan_threads)?;
    cfg.prefetch_chunks = args.get_usize("prefetch-chunks", cfg.prefetch_chunks)?;
    if let Some(v) = args.get("object-store") {
        cfg.object_store = Some(v.to_string());
    }
    if let Some(v) = args.get("engine") {
        cfg.engine = match v {
            "direct" => Engine::Direct,
            "threaded" => Engine::Threaded,
            "tcp" => Engine::Tcp,
            "cluster" => Engine::Cluster,
            _ => bail!("engine must be direct|threaded|tcp|cluster"),
        };
    }
    if let Some(v) = args.get("manifest") {
        cfg.cluster_manifest = Some(v.into());
    }
    if let Some(v) = args.get("workers") {
        cfg.cluster_workers = parse_worker_list(v);
    }
    if let Some(v) = args.get("scorer") {
        cfg.scorer = match v {
            "native" => ScorerBackend::Native,
            "xla" => ScorerBackend::Xla,
            _ => bail!("scorer must be native|xla"),
        };
    }
    if let Some(v) = args.get("artifacts-dir") {
        cfg.artifacts_dir = Some(v.into());
    }
    if let Some(v) = args.get("metrics-addr") {
        cfg.metrics_addr = Some(v.to_string());
    }
    if let Some(v) = args.get("trace-out") {
        cfg.trace_out = Some(v.into());
    }
    cfg.validate()?;

    // Bring the /metrics endpoint and the span trace sink up before any
    // training work so the first phase is already captured. The server
    // guard must outlive training: dropping it stops the listener.
    drf::telemetry::set_proc_identity("leader", None);
    let _metrics = spawn_metrics(cfg.metrics_addr.as_deref(), "train")?;
    if let Some(path) = &cfg.trace_out {
        drf::telemetry::set_trace_out(path)
            .with_context(|| format!("opening trace sink {}", path.display()))?;
    }

    let (ds, family) = dataset_from_args(&args)?;
    println!(
        "training {} trees (depth<={}) on {} [{} rows x {} features], {} splitters",
        cfg.forest.num_trees,
        cfg.forest.max_depth,
        family,
        ds.num_rows(),
        ds.num_features(),
        cfg.topology.splitters_for(ds.num_features()),
    );
    let (forest, report) = RandomForest::train_with_config(&ds, &cfg)?;
    let train_auc = auc(&forest.predict_scores(&ds), ds.labels());
    println!(
        "done in {:.2}s: {} nodes, {:.0} leaves/tree, node density {:.3}, sample density {:.3}, train AUC {:.4}",
        report.wall_seconds,
        forest.num_nodes(),
        forest.mean_leaves(),
        forest.mean_node_density(),
        forest.mean_sample_density(),
        train_auc,
    );
    println!(
        "network: {} bytes in {} messages ({} broadcasts)",
        report.net.net_bytes, report.net.net_messages, report.net.net_broadcasts
    );

    if let Some(path) = args.get("out") {
        forest.save(std::path::Path::new(path))?;
        println!("forest saved to {path}");
    }
    if let Some(path) = args.get("report") {
        std::fs::write(path, report_to_json(&report).to_string())?;
        println!("report saved to {path}");
    }
    Ok(())
}

/// Serialize a TrainReport for the --report flag.
fn report_to_json(report: &drf::coordinator::TrainReport) -> Json {
    let mut o = Json::object();
    o.set("wall_seconds", Json::Num(report.wall_seconds))
        .set("num_splitters", Json::from_usize(report.num_splitters))
        .set("net_bytes", Json::from_u64(report.net.net_bytes))
        .set("net_messages", Json::from_u64(report.net.net_messages))
        .set(
            "trees",
            Json::Arr(
                report
                    .per_tree
                    .iter()
                    .map(|t| {
                        let mut tj = Json::object();
                        tj.set("tree", Json::from_u64(t.tree as u64))
                            .set("seconds", Json::Num(t.seconds))
                            .set(
                                "levels",
                                Json::Arr(
                                    t.levels
                                        .iter()
                                        .map(|l| {
                                            let mut lj = Json::object();
                                            lj.set("depth", Json::from_u64(l.depth as u64))
                                                .set("seconds", Json::Num(l.seconds))
                                                .set("scan_seconds", Json::Num(l.scan_seconds))
                                                .set("eval_seconds", Json::Num(l.eval_seconds))
                                                .set(
                                                    "update_seconds",
                                                    Json::Num(l.update_seconds),
                                                )
                                                .set(
                                                    "open_before",
                                                    Json::from_u64(l.open_before as u64),
                                                )
                                                .set(
                                                    "open_after",
                                                    Json::from_u64(l.open_after as u64),
                                                )
                                                .set(
                                                    "num_splits",
                                                    Json::from_u64(l.num_splits as u64),
                                                )
                                                .set(
                                                    "m_double_prime",
                                                    Json::from_usize(l.m_double_prime),
                                                )
                                                .set("z", Json::from_usize(l.z_max_load))
                                                .set("net_bytes", Json::from_u64(l.net_bytes))
                                                .set(
                                                    "open_weight",
                                                    Json::from_u64(l.open_weight),
                                                );
                                            lj
                                        })
                                        .collect(),
                                ),
                            );
                        tj
                    })
                    .collect(),
            ),
        );
    o
}

/// `--workers a:1,b:2` → ["a:1", "b:2"] (shard order).
fn parse_worker_list(v: &str) -> Vec<String> {
    v.split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

/// Start the `GET /metrics` listener if `--metrics-addr` was given and
/// print a `metrics on` ready line. The returned guard must stay alive
/// for the life of the process — dropping it stops the listener.
fn spawn_metrics(
    addr: Option<&str>,
    process: &str,
) -> Result<Option<drf::telemetry::MetricsServer>> {
    let Some(addr) = addr else { return Ok(None) };
    let server = drf::telemetry::MetricsServer::spawn(addr)?;
    println!("drf {process}: metrics on {}", server.addr());
    // Flush like the main ready lines: supervisors and smoke tests read
    // this address from a piped (block-buffered) stdout.
    std::io::Write::flush(&mut std::io::stdout())?;
    Ok(Some(server))
}

/// Open the JSONL trace sink if `--trace-out` was given. Call after
/// [`drf::telemetry::set_proc_identity`] — the sink's first line
/// records the identity.
fn start_trace_out(path: Option<&str>) -> Result<()> {
    if let Some(path) = path {
        drf::telemetry::set_trace_out(std::path::Path::new(path))
            .with_context(|| format!("opening trace sink {path}"))?;
    }
    Ok(())
}

/// `drf fuzz [--target T] [--seed S] [--iters N] [--corpus DIR]
/// [--minimize] [--repro-out DIR]`: run the deterministic decoder
/// fuzzer (see [`drf::fuzz`]). Exits nonzero on any finding so CI can
/// gate on it.
fn cmd_fuzz(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, FUZZ_FLAGS)?;
    let selector = args.get_string("target", "all");
    let opts = drf::fuzz::FuzzOptions {
        targets: drf::fuzz::Target::parse_selector(&selector)?,
        seed: args.get_u64("seed", 42)?,
        iters: args.get_u64("iters", 10_000)?,
        corpus_dir: args.get("corpus").map(std::path::PathBuf::from),
        minimize: args.get_bool("minimize"),
        repro_dir: args.get("repro-out").map(std::path::PathBuf::from),
    };
    // Panicking decoders are exactly what the run hunts for; the
    // default hook would spray a backtrace per caught case. Silence it
    // for the run, restore it after.
    let default_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let report = drf::fuzz::run(&opts);
    std::panic::set_hook(default_hook);
    let report = report?;
    for line in report.lines() {
        println!("{line}");
    }
    let findings = report.num_findings();
    if findings > 0 {
        bail!("fuzzing found {findings} decoder invariant violation(s)");
    }
    Ok(())
}

/// `drf metrics ADDR [--watch] [--interval-ms MS]`: scrape a running
/// process's `/metrics` endpoint and print the Prometheus text. In
/// watch mode every sample that changed since the previous scrape is
/// annotated with its per-second rate.
fn cmd_metrics(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, METRICS_FLAGS)?;
    let addr = args
        .positional()
        .first()
        .context("usage: drf metrics ADDR [--watch] [--interval-ms MS]")?
        .clone();
    let watch = args.get_bool("watch");
    let interval = std::time::Duration::from_millis(args.get_u64("interval-ms", 2000)?);
    let mut prev: Option<(String, std::time::Instant)> = None;
    loop {
        let body = drf::telemetry::scrape(&addr)
            .with_context(|| format!("scraping metrics from {addr}"))?;
        let now = std::time::Instant::now();
        match &prev {
            Some((prev_body, prev_at)) => print!(
                "{}",
                annotate_rates(prev_body, &body, now.duration_since(*prev_at).as_secs_f64())
            ),
            None => print!("{body}"),
        }
        std::io::Write::flush(&mut std::io::stdout())?;
        if !watch {
            return Ok(());
        }
        println!("--- {addr}");
        prev = Some((body, now));
        std::thread::sleep(interval);
    }
}

/// Split a Prometheus text line into `(series, value)`; comments and
/// anything non-numeric pass through as `None`.
fn split_sample(line: &str) -> Option<(&str, f64)> {
    if line.starts_with('#') {
        return None;
    }
    let (series, value) = line.rsplit_once(' ')?;
    Some((series, value.parse().ok()?))
}

/// Annotate a `/metrics` snapshot with per-second rates against the
/// previous scrape: every sample whose value changed gains a
/// ` ({delta:+}/s)` suffix. Pure text-to-text so it is unit-testable
/// without a live endpoint.
fn annotate_rates(prev: &str, cur: &str, secs: f64) -> String {
    let old: std::collections::HashMap<&str, f64> =
        prev.lines().filter_map(split_sample).collect();
    let mut out = String::with_capacity(cur.len());
    for line in cur.lines() {
        out.push_str(line);
        if secs > 0.0 {
            if let Some((series, value)) = split_sample(line) {
                if let Some(&p) = old.get(series) {
                    if value != p {
                        out.push_str(&format!(" ({:+.1}/s)", (value - p) / secs));
                    }
                }
            }
        }
        out.push('\n');
    }
    out
}

/// `drf trace merge FILE... --out trace.json` / `drf trace report
/// FILE...`: stitch per-process `--trace-out` files into one
/// clock-aligned timeline (Chrome trace-event JSON for Perfetto), or
/// print the per-round straggler report.
fn cmd_trace(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, TRACE_FLAGS)?;
    let usage = "usage: drf trace merge FILE... --out trace.json | drf trace report FILE...";
    let (mode, files) = args.positional().split_first().context(usage)?;
    if files.is_empty() {
        bail!("no trace files given ({usage})");
    }
    match mode.as_str() {
        "merge" => {
            let out = args.require("out")?;
            let merged =
                drf::telemetry::trace::merge_to_file(files, std::path::Path::new(out))?;
            println!(
                "merged {} process timeline(s), {} spans -> {out}",
                merged.files.len(),
                merged.files.iter().map(|f| f.spans.len()).sum::<usize>(),
            );
            if !merged.unaligned.is_empty() {
                eprintln!(
                    "warning: no clock_sync path to the leader for pid(s) {:?}; \
                     their timelines are unaligned",
                    merged.unaligned
                );
            }
            Ok(())
        }
        "report" => {
            let merged = drf::telemetry::trace::merge_files(files)?;
            print!("{}", merged.report());
            Ok(())
        }
        other => bail!("unknown trace subcommand '{other}' ({usage})"),
    }
}

fn cmd_shard(argv: &[String]) -> Result<()> {
    let mut flags = TRAIN_FLAGS.to_vec();
    flags.extend(["out-dir", "chunk-rows", "replicas"]);
    let args = Args::parse(argv, &flags)?;
    let out = args.require("out-dir")?;
    let (ds, family) = dataset_from_args(&args)?;
    let mut topo = drf::config::TopologyParams::default();
    if let Some(v) = args.get("splitters") {
        topo.num_splitters = Some(v.parse()?);
    }
    topo.redundancy = args.get_usize("redundancy", topo.redundancy)?;
    topo.validate()?;
    let mut opts = drf::cluster::ShardOptions::default();
    opts.chunk_rows = args.get_u32("chunk-rows", opts.chunk_rows)?;
    opts.replicas = args.get_usize("replicas", opts.replicas)?;
    if let Some(v) = args.get("workers") {
        opts.workers = parse_worker_list(v);
    }
    let out_dir = std::path::Path::new(out);
    let cluster = drf::cluster::write_shards(
        &ds,
        &topo,
        out_dir,
        &opts,
        drf::data::io_stats::IoStats::new(),
    )?;
    println!(
        "sharded {family} ({} rows x {} features) into {} packs (redundancy {}) under {out}",
        cluster.rows, cluster.num_features, cluster.num_splitters, cluster.redundancy
    );
    println!(
        "cluster manifest: {}",
        out_dir.join(drf::cluster::ClusterManifest::FILE).display()
    );
    println!("serve each pack:   drf worker --shard {out}/shard_<i> --addr HOST:PORT");
    println!(
        "then train:        drf train --engine cluster --manifest {} --workers ...",
        out_dir.join(drf::cluster::ClusterManifest::FILE).display()
    );
    Ok(())
}

/// `drf objstore --dir DIR [--addr HOST:PORT] [--fail-after N]`: serve
/// byte ranges of DIR until killed (or until the `--fail-after`
/// crash-simulation limit fires, which exits the process).
fn cmd_objstore(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, OBJSTORE_FLAGS)?;
    let dir = args.require("dir")?;
    let addr = args.get_string("addr", "127.0.0.1:0");
    let opts = drf::data::objserve::ObjStoreOptions {
        fail_after_reads: match args.get("fail-after") {
            Some(v) => Some(v.parse()?),
            None => None,
        },
        exit_process_on_limit: true,
    };
    drf::telemetry::set_proc_identity("objstore", None);
    start_trace_out(args.get("trace-out"))?;
    let server = drf::data::objserve::ObjStoreServer::spawn(
        std::path::Path::new(dir),
        &addr,
        drf::data::io_stats::IoStats::new(),
        opts,
    )?;
    println!("drf objstore: serving {dir} on {}", server.addr());
    // Flush explicitly: a piped stdout (the smoke tests, a process
    // supervisor) is block-buffered and would otherwise hold the ready
    // line back indefinitely.
    std::io::Write::flush(&mut std::io::stdout())?;
    // Second ready line — parsers of the first line are unaffected.
    let _metrics = spawn_metrics(args.get("metrics-addr"), "objstore")?;
    // Serve until killed; requests are handled by the server's
    // accept/connection threads.
    loop {
        std::thread::park();
    }
}

fn cmd_worker(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, WORKER_FLAGS)?;
    let dir = args.require("shard")?;
    let addr = args.get_string("addr", "127.0.0.1:0");
    let opts = drf::cluster::WorkerOptions {
        scan_threads: args.get_usize("scan-threads", 1)?,
        preload: args.get_bool("preload"),
        verify: !args.get_bool("no-verify"),
        prefetch_chunks: args.get_usize("prefetch-chunks", 0)?,
    };
    let (shard, source, mode) = match args.get("object-store") {
        // Remote pack: `--shard` names the pack's directory under the
        // objstore root (e.g. shard_0); nothing is downloaded in full.
        // The address may be a comma-separated replica list — the
        // client rotates through it on failure.
        Some(objstore) => (
            drf::cluster::load_shard_remote(objstore, dir, &opts)?,
            drf::cluster::ShardSource::Remote {
                addr: objstore.to_string(),
                prefix: dir.to_string(),
            },
            format!("remote:{objstore}"),
        ),
        None => (
            drf::cluster::load_shard(std::path::Path::new(dir), &opts)?,
            drf::cluster::ShardSource::Dir(std::path::PathBuf::from(dir)),
            if opts.preload { "mmapped".into() } else { "streaming".into() },
        ),
    };
    let (id, cols, rows) = (
        shard.manifest.shard,
        shard.manifest.columns.len(),
        shard.manifest.rows,
    );
    drf::telemetry::set_proc_identity("worker", Some(id as u64));
    start_trace_out(args.get("trace-out"))?;
    let server = drf::cluster::WorkerServer::spawn_with_source(
        shard,
        Some((source, opts.clone())),
        &addr,
        opts.scan_threads,
    )?;
    println!(
        "drf worker: shard {id} ({cols} columns x {rows} rows, {mode}) listening on {}",
        server.addr(),
    );
    // Flush explicitly: a piped stdout (the cluster smoke test, a
    // process supervisor) is block-buffered and would otherwise hold
    // the ready line back indefinitely.
    std::io::Write::flush(&mut std::io::stdout())?;
    // Second ready line — parsers of the first line are unaffected.
    let _metrics = spawn_metrics(args.get("metrics-addr"), "worker")?;
    // Serve until killed; connections are handled by the server's
    // accept/worker threads.
    loop {
        std::thread::park();
    }
}

/// `drf supervise --dir DIR`: boot the sharded fleet from `cluster.json`
/// and keep it alive — probe every process, restart or reschedule the
/// dead, and publish each topology change as a manifest version bump.
/// With `--drain I` it instead performs the offline re-shard (move
/// worker I's columns onto the survivors) and exits.
fn cmd_supervise(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, SUPERVISE_FLAGS)?;
    let dir = args.require("dir")?;
    if let Some(v) = args.get("drain") {
        // Offline mode: no fleet, just the manifest/pack rewrite. A
        // live drain goes through the control channel instead.
        let victim: usize = v.parse().context("--drain expects a shard index")?;
        let m = drf::cluster::drain_worker(std::path::Path::new(dir), victim)?;
        println!(
            "drf supervise: drained worker {victim}; {} now v{}",
            std::path::Path::new(dir).join("cluster.json").display(),
            m.version
        );
        return Ok(());
    }
    drf::telemetry::set_proc_identity("supervisor", None);
    start_trace_out(args.get("trace-out"))?;
    // Keep the guard alive for the life of the supervisor loop.
    let _metrics = spawn_metrics(args.get("metrics-addr"), "supervise")?;
    // Flags the supervisor forwards verbatim to every worker it spawns.
    let mut worker_args = Vec::new();
    for flag in ["scan-threads", "prefetch-chunks"] {
        if let Some(v) = args.get(flag) {
            worker_args.push(format!("--{flag}"));
            worker_args.push(v.to_string());
        }
    }
    for flag in ["preload", "no-verify"] {
        if args.get_bool(flag) {
            worker_args.push(format!("--{flag}"));
        }
    }
    let policy = drf::cluster::SupervisePolicy {
        fail_threshold: args.get_u32("fail-threshold", 2)?,
        ..Default::default()
    };
    let opts = drf::cluster::SuperviseOptions {
        interval: std::time::Duration::from_millis(args.get_u64("interval-ms", 500)?),
        policy,
        spare_hosts: args.get("spare-hosts").map(parse_worker_list).unwrap_or_default(),
        control_addr: args.get("control-addr").map(str::to_string),
        action_log: args.get("log").map(std::path::PathBuf::from),
        objstore_replicas: args.get_usize("objstore-replicas", 0)?,
        worker_args,
        trace_dir: args.get("trace-dir").map(std::path::PathBuf::from),
        binary: None,
    };
    if let Some(d) = &opts.trace_dir {
        std::fs::create_dir_all(d).with_context(|| format!("creating {}", d.display()))?;
    }
    drf::cluster::Supervisor::run(std::path::Path::new(dir), &opts)
}

fn cmd_generate(argv: &[String]) -> Result<()> {
    let mut flags = TRAIN_FLAGS.to_vec();
    flags.extend(["out-dir", "chunk-rows"]);
    let args = Args::parse(argv, &flags)?;
    let out = args.get("out-dir").context("--out-dir is required")?;
    let (ds, family) = dataset_from_args(&args)?;
    // --chunk-rows C writes the chunk-tabled DRFC v2 layout — the one
    // `drf objstore` + `--storage remote` range reads map onto.
    let layout = match args.get("chunk-rows") {
        Some(v) => drf::data::disk::Layout::V2 { chunk_rows: v.parse()? },
        None => drf::data::disk::Layout::V1,
    };
    drf::data::store::save_dataset_with(
        &ds,
        std::path::Path::new(out),
        layout,
        drf::data::io_stats::IoStats::new(),
    )?;
    println!(
        "wrote {} ({} rows x {} features, presorted) to {out}",
        family,
        ds.num_rows(),
        ds.num_features()
    );
    Ok(())
}

fn cmd_evaluate(argv: &[String]) -> Result<()> {
    let mut flags = TRAIN_FLAGS.to_vec();
    flags.push("model");
    let args = Args::parse(argv, &flags)?;
    let model = args.get("model").context("--model is required")?;
    let forest = RandomForest::load(std::path::Path::new(model))?;
    let (ds, family) = dataset_from_args(&args)?;
    // Compile once, score and classify on the same flat forest.
    let flat = forest.compile();
    flat.check_dataset(&ds)?;
    let opts = drf::serve::BatchOptions::default();
    let scores = flat.predict_scores_batch(&ds, &opts);
    let a = auc(&scores, ds.labels());
    let preds = flat.predict_classes_batch(&ds, &opts);
    let acc = drf::metrics::accuracy(&preds, ds.labels());
    println!(
        "{}: {} rows — AUC {:.4}, accuracy {:.4} ({} trees)",
        family,
        ds.num_rows(),
        a,
        acc,
        forest.num_trees()
    );
    Ok(())
}

fn cmd_importance(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["model", "features"])?;
    let model = args.get("model").context("--model is required")?;
    let forest = RandomForest::load(std::path::Path::new(model))?;
    let m = args.get_usize(
        "features",
        forest
            .trees
            .iter()
            .flat_map(|t| t.nodes.iter())
            .filter_map(|n| n.condition.as_ref().map(|c| c.feature() + 1))
            .max()
            .unwrap_or(1),
    )?;
    let imp = mdi_importance(&forest, m);
    for f in rank_features(&imp) {
        println!("feature {f}: {:.4}", imp[f]);
    }
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, SERVE_FLAGS)?;
    let model = args.require("model")?;
    let addr = args.get_string("addr", "127.0.0.1:7878");
    let path = std::path::PathBuf::from(model);
    let forest = RandomForest::load(&path)?;
    drf::telemetry::set_proc_identity("serve", None);
    start_trace_out(args.get("trace-out"))?;
    // The server compiles the forest itself; don't flatten twice.
    let server = drf::serve::PredictionServer::spawn(&forest, &addr, Some(path))?;
    println!(
        "serving {} trees / {} nodes ({} classes) on {}",
        forest.num_trees(),
        forest.num_nodes(),
        forest.num_classes,
        server.addr(),
    );
    println!("RPCs: Score, Classify, ModelInfo, Reload (hot). Ctrl-C to stop.");
    std::io::Write::flush(&mut std::io::stdout())?;
    let _metrics = spawn_metrics(args.get("metrics-addr"), "serve")?;
    // Serve until killed; connections are handled by the server's
    // accept/worker threads.
    loop {
        std::thread::park();
    }
}

fn cmd_predict(argv: &[String]) -> Result<()> {
    let mut flags = TRAIN_FLAGS.to_vec();
    flags.extend(["model", "addr", "show"]);
    let args = Args::parse(argv, &flags)?;
    let (ds, family) = dataset_from_args(&args)?;
    let (scores, classes, source) = match args.get("addr") {
        Some(addr) => {
            let mut client = drf::serve::PredictClient::connect(addr)?;
            let info = client.model_info()?;
            println!(
                "connected to {addr}: {} trees / {} nodes, {} classes",
                info.num_trees, info.num_nodes, info.num_classes
            );
            (
                client.score_dataset(&ds)?,
                client.classify_dataset(&ds)?,
                format!("tcp:{addr}"),
            )
        }
        None => {
            let model = args.get("model").context(
                "predict needs --addr (remote server) or --model (local scoring)",
            )?;
            let forest = RandomForest::load(std::path::Path::new(model))?;
            let flat = forest.compile();
            flat.check_dataset(&ds)?;
            let opts = drf::serve::BatchOptions::default();
            (
                flat.predict_scores_batch(&ds, &opts),
                flat.predict_classes_batch(&ds, &opts),
                format!("local:{model}"),
            )
        }
    };
    let a = auc(&scores, ds.labels());
    let acc = drf::metrics::accuracy(&classes, ds.labels());
    println!(
        "{family} via {source}: {} rows — AUC {a:.4}, accuracy {acc:.4}",
        ds.num_rows()
    );
    for i in 0..args.get_usize("show", 0)?.min(ds.num_rows()) {
        println!("row {i}: score {:.4}, class {}", scores[i], classes[i]);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every flag a command accepts must appear in HELP as `--name`
    /// (`!` marks boolean switches and is not part of the flag name).
    fn assert_flags_documented(which: &str, flags: &[&str]) {
        for f in flags {
            let name = f.strip_prefix('!').unwrap_or(f);
            assert!(
                HELP.contains(&format!("--{name}")),
                "{which} flag --{name} is not documented in HELP"
            );
        }
    }

    #[test]
    fn help_documents_every_flag() {
        assert_flags_documented("train", TRAIN_FLAGS);
        assert_flags_documented("worker", WORKER_FLAGS);
        assert_flags_documented("objstore", OBJSTORE_FLAGS);
        assert_flags_documented("serve", SERVE_FLAGS);
        assert_flags_documented("metrics", METRICS_FLAGS);
        assert_flags_documented("trace", TRACE_FLAGS);
        assert_flags_documented("supervise", SUPERVISE_FLAGS);
        assert_flags_documented("fuzz", FUZZ_FLAGS);
        // Extra flags the derived commands add on top of TRAIN_FLAGS.
        assert_flags_documented("shard/generate", &["out-dir", "chunk-rows"]);
        assert_flags_documented("shard", &["replicas"]);
        assert_flags_documented("evaluate/predict", &["model", "addr", "show"]);
        assert_flags_documented("importance", &["model", "features"]);
    }

    #[test]
    fn help_documents_every_command() {
        for cmd in [
            "train",
            "generate",
            "shard",
            "objstore",
            "worker",
            "supervise",
            "evaluate",
            "importance",
            "serve",
            "predict",
            "metrics",
            "trace",
            "fuzz",
            "info",
        ] {
            assert!(
                HELP.contains(&format!("drf {cmd}")),
                "HELP does not document `drf {cmd}`"
            );
        }
    }

    #[test]
    fn watch_rates_annotate_changed_samples() {
        let prev = "# TYPE a_total counter\na_total 10\nb_total{x=\"1\"} 5\ng 3\n";
        let cur = "# TYPE a_total counter\na_total 30\nb_total{x=\"1\"} 5\ng 2\n";
        let out = annotate_rates(prev, cur, 2.0);
        // Changed counter gains a rate; unchanged sample and comments
        // pass through untouched; falling gauges get a signed rate.
        assert!(out.contains("a_total 30 (+10.0/s)"), "{out}");
        assert!(out.contains("b_total{x=\"1\"} 5\n"), "{out}");
        assert!(!out.contains("b_total{x=\"1\"} 5 ("), "{out}");
        assert!(out.contains("# TYPE a_total counter\n"), "{out}");
        assert!(out.contains("g 2 (-0.5/s)"), "{out}");
        // A zero interval (clock glitch) must not divide by zero.
        assert_eq!(annotate_rates(prev, cur, 0.0), cur);
        // First scrape: series the previous snapshot lacked stay bare.
        let out = annotate_rates("", cur, 2.0);
        assert!(!out.contains("/s)"), "{out}");
    }
}

fn cmd_info() -> Result<()> {
    println!("drf {} — exact distributed Random Forest", env!("CARGO_PKG_VERSION"));
    match drf::runtime::XlaRuntime::cpu() {
        Ok(rt) => println!("PJRT platform: {}", rt.platform_name()),
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    for (b, t) in [(16usize, 512usize), (4, 64)] {
        let name = drf::splits::xla_scorer::XlaScorer::artifact_name(b, t);
        let path = std::path::Path::new("artifacts").join(&name);
        println!(
            "artifact {name}: {}",
            if path.exists() { "present" } else { "missing (run `make artifacts`)" }
        );
    }
    Ok(())
}
