//! TCP transport: splitters served over real sockets.
//!
//! The in-process engines already account every byte; this module makes
//! the distribution *literal* — each splitter runs a blocking
//! request/response server on a TCP listener (one thread per
//! connection), and [`TcpPool`] implements [`SplitterPool`] over
//! persistent client connections using the binary codec in
//! [`super::wire`]. Exactness over TCP is asserted in the tests below:
//! the same trees come out whether workers share an address space or
//! talk through the loopback stack.

use super::messages::{
    EvalQuery, EvalResult, LevelUpdate, MaterializeQuery, MaterializedLeaves, PartialSupersplit,
    SubtreeDone, SupersplitQuery,
};
use super::splitter::SplitterCore;
use super::transport::SplitterPool;
use super::wire::{
    decode_request_traced, decode_response, encode_request_traced, encode_response, read_frame,
    write_frame, HelloInfo, Request, Response, PROTOCOL_VERSION,
};
use crate::data::io_stats::IoStats;
use crate::telemetry::{adopt_remote_context, current_context, time_sync_reply};
use crate::Result;
use anyhow::{bail, Context};
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};

/// A splitter served over TCP. Dropping the server stops accepting new
/// connections (in-flight connections end when their peer disconnects).
pub struct SplitterServer {
    addr: std::net::SocketAddr,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    shutdown: Arc<std::sync::atomic::AtomicBool>,
}

impl SplitterServer {
    /// Serve `core` on an ephemeral localhost port.
    pub fn spawn(core: Arc<SplitterCore>) -> Result<SplitterServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let shutdown2 = shutdown.clone();
        let accept_handle = std::thread::Builder::new()
            .name(format!("drf-splitter-{}", core.id()))
            .spawn(move || {
                for conn in listener.incoming() {
                    if shutdown2.load(std::sync::atomic::Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { break };
                    let core = core.clone();
                    // One thread per connection (a tree builder keeps one
                    // persistent connection).
                    let _ = std::thread::Builder::new()
                        .name("drf-splitter-conn".into())
                        .spawn(move || {
                            let _ = serve_connection(&core, stream);
                        });
                }
            })?;
        Ok(SplitterServer {
            addr,
            accept_handle: Some(accept_handle),
            shutdown,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }
}

impl Drop for SplitterServer {
    fn drop(&mut self) {
        self.shutdown
            .store(true, std::sync::atomic::Ordering::SeqCst);
        // Poke the listener so the accept loop wakes and exits.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

/// Handle one connection's request loop.
fn serve_connection(core: &SplitterCore, stream: TcpStream) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        let frame = match read_frame(&mut reader) {
            Ok(f) => f,
            Err(_) => return Ok(()), // peer closed
        };
        let response = match decode_request_traced(&frame) {
            Err(e) => Response::Err(format!("bad request: {e}")),
            Ok((Request::Shutdown, _)) => {
                write_frame(&mut writer, &encode_response(&Response::Ok))?;
                return Ok(());
            }
            Ok((req, ctx)) => {
                // Spans opened while serving this request parent under
                // the caller's span (when it sent context).
                let _trace = adopt_remote_context(ctx.as_ref());
                handle_request(core, req)
            }
        };
        write_frame(&mut writer, &encode_response(&response))?;
    }
}

/// Dispatch one decoded request against a configured splitter core.
/// Shared by the in-process [`SplitterServer`] and the standalone
/// cluster worker ([`crate::cluster::worker`]), which adds its own
/// Hello/configuration handling on top.
pub(crate) fn handle_request(core: &SplitterCore, req: Request) -> Response {
    match req {
        Request::StartTree(t) => {
            core.start_tree(t);
            Response::Ok
        }
        Request::RootStats(t) => Response::RootStats(core.root_stats(t)),
        Request::FindSplits(q) => match core.find_splits(&q) {
            Ok(p) => Response::Splits(p),
            Err(e) => Response::Err(format!("{e}")),
        },
        Request::EvalConditions(q) => match core.eval_conditions(&q) {
            Ok(r) => Response::Evals(r),
            Err(e) => Response::Err(format!("{e}")),
        },
        Request::LevelUpdate(u) => match core.apply_level_update(&u) {
            Ok(()) => Response::Ok,
            Err(e) => Response::Err(format!("{e}")),
        },
        Request::Materialize(q) => match core.materialize(&q) {
            Ok(m) => Response::Materialized(m),
            Err(e) => Response::Err(format!("{e}")),
        },
        Request::SubtreeDone(d) => match core.subtree_done(&d) {
            Ok(()) => Response::Ok,
            Err(e) => Response::Err(format!("{e}")),
        },
        Request::FinishTree(t) => {
            core.finish_tree(t);
            Response::Ok
        }
        Request::Shutdown => Response::Ok,
        Request::TimeSync => Response::TimeSync(time_sync_reply()),
        Request::Hello(h) => {
            // The core is already configured (in-process servers) — the
            // handshake validates identity and reports the inventory.
            if h.protocol != PROTOCOL_VERSION {
                Response::Err(format!(
                    "protocol mismatch: peer speaks v{}, this splitter v{PROTOCOL_VERSION}",
                    h.protocol
                ))
            } else if h.shard as usize != core.id() {
                Response::Err(format!(
                    "shard mismatch: peer expects shard {}, this is splitter {}",
                    h.shard,
                    core.id()
                ))
            } else {
                Response::Hello(hello_info_for(core))
            }
        }
    }
}

/// The inventory a splitter core reports in the Hello handshake.
pub(crate) fn hello_info_for(core: &SplitterCore) -> HelloInfo {
    HelloInfo {
        protocol: PROTOCOL_VERSION,
        shard: core.id() as u32,
        rows: core.num_rows() as u64,
        num_classes: core.num_classes(),
        columns: core.columns_owned().iter().map(|&c| c as u32).collect(),
    }
}

/// One persistent client connection (mutex-guarded: requests on a
/// connection are serialized, which matches the RPC semantics).
struct Client {
    reader: Mutex<(BufReader<TcpStream>, BufWriter<TcpStream>)>,
    columns: Vec<usize>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr, columns: Vec<usize>) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to splitter at {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(Client {
            reader: Mutex::new((
                BufReader::new(stream.try_clone()?),
                BufWriter::new(stream),
            )),
            columns,
        })
    }

    fn call(&self, req: &Request, net: &IoStats) -> Result<Response> {
        let ctx = current_context();
        let body = encode_request_traced(req, ctx.as_ref());
        let mut guard = self.reader.lock().unwrap();
        net.add_net(body.len() as u64 + 4);
        write_frame(&mut guard.1, &body)?;
        let resp_frame = read_frame(&mut guard.0)?;
        net.add_net(resp_frame.len() as u64 + 4);
        let resp = decode_response(&resp_frame)?;
        if let Response::Err(msg) = &resp {
            bail!("{msg}");
        }
        Ok(resp)
    }
}

/// A [`SplitterPool`] backed by TCP connections to splitter servers.
pub struct TcpPool {
    clients: Vec<Client>,
    net: IoStats,
}

impl TcpPool {
    /// Connect to the given splitter addresses. `columns[i]` must match
    /// what splitter `i` actually owns (used for routing only).
    pub fn connect(addrs: &[std::net::SocketAddr], columns: Vec<Vec<usize>>) -> Result<TcpPool> {
        anyhow::ensure!(addrs.len() == columns.len());
        let clients = addrs
            .iter()
            .zip(columns)
            .map(|(&a, cols)| Client::connect(a, cols))
            .collect::<Result<_>>()?;
        Ok(TcpPool {
            clients,
            net: IoStats::new(),
        })
    }
}

impl SplitterPool for TcpPool {
    fn num_splitters(&self) -> usize {
        self.clients.len()
    }

    fn columns_of(&self, splitter: usize) -> Vec<usize> {
        self.clients[splitter].columns.clone()
    }

    fn start_tree(&self, tree: u32) -> Result<()> {
        for s in 0..self.clients.len() {
            self.start_tree_on(s, tree)?;
        }
        Ok(())
    }

    fn root_stats(&self, splitter: usize, tree: u32) -> Result<Vec<u64>> {
        match self.clients[splitter].call(&Request::RootStats(tree), &self.net)? {
            Response::RootStats(v) => Ok(v),
            r => bail!("unexpected response {r:?}"),
        }
    }

    fn find_splits(&self, splitter: usize, q: &SupersplitQuery) -> Result<PartialSupersplit> {
        match self.clients[splitter].call(&Request::FindSplits(q.clone()), &self.net)? {
            Response::Splits(p) => Ok(p),
            r => bail!("unexpected response {r:?}"),
        }
    }

    fn eval_conditions(&self, splitter: usize, q: &EvalQuery) -> Result<EvalResult> {
        match self.clients[splitter].call(&Request::EvalConditions(q.clone()), &self.net)? {
            Response::Evals(e) => Ok(e),
            r => bail!("unexpected response {r:?}"),
        }
    }

    fn broadcast_level_update(&self, u: &LevelUpdate) -> Result<()> {
        for s in 0..self.clients.len() {
            self.apply_level_update_on(s, u)?;
        }
        // Bytes/messages were charged per peer; count the event.
        self.net.add_broadcast_event();
        Ok(())
    }

    fn materialize(&self, splitter: usize, q: &MaterializeQuery) -> Result<MaterializedLeaves> {
        match self.clients[splitter].call(&Request::Materialize(q.clone()), &self.net)? {
            Response::Materialized(m) => Ok(m),
            r => bail!("unexpected response {r:?}"),
        }
    }

    fn broadcast_subtree_done(&self, d: &SubtreeDone) -> Result<()> {
        for s in 0..self.clients.len() {
            self.broadcast_subtree_done_on(s, d)?;
        }
        self.net.add_broadcast_event();
        Ok(())
    }

    fn finish_tree(&self, tree: u32) -> Result<()> {
        for s in 0..self.clients.len() {
            self.finish_tree_on(s, tree)?;
        }
        Ok(())
    }

    fn net_stats(&self) -> IoStats {
        self.net.clone()
    }

    fn start_tree_on(&self, splitter: usize, tree: u32) -> Result<()> {
        match self.clients[splitter].call(&Request::StartTree(tree), &self.net)? {
            Response::Ok => Ok(()),
            r => bail!("unexpected response {r:?}"),
        }
    }

    fn apply_level_update_on(&self, splitter: usize, u: &LevelUpdate) -> Result<()> {
        match self.clients[splitter].call(&Request::LevelUpdate(u.clone()), &self.net)? {
            Response::Ok => Ok(()),
            r => bail!("unexpected response {r:?}"),
        }
    }

    fn finish_tree_on(&self, splitter: usize, tree: u32) -> Result<()> {
        match self.clients[splitter].call(&Request::FinishTree(tree), &self.net)? {
            Response::Ok => Ok(()),
            r => bail!("unexpected response {r:?}"),
        }
    }

    fn broadcast_subtree_done_on(&self, splitter: usize, d: &SubtreeDone) -> Result<()> {
        match self.clients[splitter].call(&Request::SubtreeDone(*d), &self.net)? {
            Response::Ok => Ok(()),
            r => bail!("unexpected response {r:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ForestParams, PruneMode, SplitSearch, TopologyParams};
    use crate::coordinator::splitter::{memory_storage_for, SplitterConfig};
    use crate::coordinator::topology::Topology;
    use crate::coordinator::transport::DirectPool;
    use crate::coordinator::tree_builder::TreeBuilderCore;
    use crate::data::synthetic::{Family, SyntheticSpec};
    use crate::rng::{Bagger, BaggingMode, FeatureSampling};
    use crate::splits::scorer::ScoreKind;

    #[test]
    fn tcp_training_matches_in_process() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 3 }, 400, 6, 5).generate();
        let params = ForestParams {
            num_trees: 2,
            max_depth: 6,
            bagging: BaggingMode::Poisson,
            seed: 91,
            ..Default::default()
        };
        let topo_params = TopologyParams {
            num_splitters: Some(3),
            ..Default::default()
        };
        let topology = Topology::new(ds.num_features(), &topo_params);
        let labels = std::sync::Arc::new(ds.labels().to_vec());
        let cfg = SplitterConfig {
            seed: params.seed,
            bagger: Bagger::new(params.seed, params.bagging),
            feature_sampling: FeatureSampling::PerNode,
            num_candidates: params.candidates_for(ds.num_features()),
            score_kind: ScoreKind::Gini,
            prune: PruneMode::Never,
            scan_threads: 1,
            split_search: SplitSearch::Exact,
        };
        let make_cores = || -> Vec<Arc<SplitterCore>> {
            (0..topology.num_splitters())
                .map(|s| {
                    Arc::new(SplitterCore::new(
                        s,
                        ds.schema().clone(),
                        memory_storage_for(&ds, &topology.columns_of(s)),
                        labels.clone(),
                        cfg,
                        IoStats::new(),
                    ))
                })
                .collect()
        };

        // Reference: in-process.
        let direct = DirectPool::new(make_cores(), 0);
        let builder = TreeBuilderCore::new(&direct, &topology, &params, ds.num_features());
        let reference: Vec<_> = (0..2).map(|t| builder.build_tree(t).unwrap().0).collect();

        // Same cores behind real sockets.
        let servers: Vec<SplitterServer> = make_cores()
            .into_iter()
            .map(|c| SplitterServer::spawn(c).unwrap())
            .collect();
        let addrs: Vec<_> = servers.iter().map(|s| s.addr()).collect();
        let columns: Vec<_> = (0..topology.num_splitters())
            .map(|s| topology.columns_of(s))
            .collect();
        let pool = TcpPool::connect(&addrs, columns).unwrap();
        let builder = TreeBuilderCore::new(&pool, &topology, &params, ds.num_features());
        let over_tcp: Vec<_> = (0..2).map(|t| builder.build_tree(t).unwrap().0).collect();

        assert_eq!(reference, over_tcp, "TCP transport must preserve exactness");
        assert!(pool.net_stats().net_bytes() > 0, "bytes actually moved");
    }

    #[test]
    fn server_reports_errors_as_responses() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 50, 4, 5).generate();
        let labels = std::sync::Arc::new(ds.labels().to_vec());
        let cfg = SplitterConfig {
            seed: 1,
            bagger: Bagger::new(1, BaggingMode::None),
            feature_sampling: FeatureSampling::All,
            num_candidates: 4,
            score_kind: ScoreKind::Gini,
            prune: PruneMode::Never,
            scan_threads: 1,
            split_search: SplitSearch::Exact,
        };
        let core = Arc::new(SplitterCore::new(
            0,
            ds.schema().clone(),
            memory_storage_for(&ds, &[0, 1, 2, 3]),
            labels,
            cfg,
            IoStats::new(),
        ));
        let server = SplitterServer::spawn(core).unwrap();
        let pool = TcpPool::connect(&[server.addr()], vec![vec![0, 1, 2, 3]]).unwrap();
        // Querying an unknown tree must surface as a clean error.
        let q = SupersplitQuery {
            tree: 99,
            depth: 0,
            leaves: vec![],
            assigned_columns: vec![0],
        };
        let err = pool.find_splits(0, &q).unwrap_err();
        assert!(format!("{err}").contains("unknown tree"), "{err}");
    }
}
