//! Worker wiring: how tree builders reach splitters.
//!
//! [`SplitterPool`] is the RPC surface of Alg. 2. Two implementations:
//!
//! * [`DirectPool`] — in-process calls with full network *accounting*
//!   (every request/response is charged its wire size, broadcasts are
//!   charged fanout × size) and optional injected latency. Deterministic
//!   and fast; used by exactness tests and most benches.
//! * `ThreadedPool` (in [`super::manager`]) — each splitter runs on its
//!   own OS thread behind a request channel; same byte accounting.
//!
//! Both charge identical byte counts for identical traffic, so network
//! metrics are engine-independent.

use super::messages::{
    EvalQuery, EvalResult, LevelUpdate, MaterializeQuery, MaterializedLeaves, PartialSupersplit,
    SubtreeDone, SupersplitQuery,
};
use super::splitter::SplitterCore;
use crate::data::io_stats::IoStats;
use crate::Result;
use std::sync::Arc;

/// The tree builder's view of the splitter fleet — the RPC surface of
/// Alg. 2. Every engine (`direct`, `threaded`, `tcp`, `cluster`)
/// implements this same trait, which is why they are interchangeable
/// and bit-identical.
///
/// # Examples
///
/// [`DirectPool`] is the in-process implementation; the calls below
/// are exactly what a tree builder issues per tree (network traffic is
/// accounted even without a network):
///
/// ```
/// use std::sync::Arc;
/// use drf::config::{PruneMode, SplitSearch};
/// use drf::coordinator::splitter::{memory_storage_for, SplitterConfig, SplitterCore};
/// use drf::coordinator::transport::{DirectPool, SplitterPool};
/// use drf::data::io_stats::IoStats;
/// use drf::data::synthetic::{Family, SyntheticSpec};
/// use drf::rng::{Bagger, BaggingMode, FeatureSampling};
/// use drf::splits::scorer::ScoreKind;
///
/// let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 60, 4, 1).generate();
/// let labels = Arc::new(ds.labels().to_vec());
/// let cfg = SplitterConfig {
///     seed: 1,
///     bagger: Bagger::new(1, BaggingMode::None),
///     feature_sampling: FeatureSampling::All,
///     num_candidates: 4,
///     score_kind: ScoreKind::Gini,
///     prune: PruneMode::Never,
///     scan_threads: 1,
///     split_search: SplitSearch::Exact,
/// };
/// // Two splitters, each owning half the columns (round-robin).
/// let splitters = (0..2)
///     .map(|s| {
///         let cols: Vec<usize> = (0..4).filter(|j| j % 2 == s).collect();
///         Arc::new(SplitterCore::new(
///             s,
///             ds.schema().clone(),
///             memory_storage_for(&ds, &cols),
///             labels.clone(),
///             cfg,
///             IoStats::new(),
///         ))
///     })
///     .collect();
/// let pool = DirectPool::new(splitters, 0);
///
/// pool.start_tree(0)?;
/// let hist = pool.root_stats(0, 0)?;         // splitter 0's bagged class counts
/// assert_eq!(hist.iter().sum::<u64>(), 60);  // BaggingMode::None: every row, weight 1
/// assert_eq!(pool.columns_of(1), vec![1, 3]);
/// pool.finish_tree(0)?;
/// assert!(pool.net_stats().net_bytes() > 0); // traffic accounted even in-process
/// # Ok::<(), anyhow::Error>(())
/// ```
pub trait SplitterPool: Send + Sync {
    /// Size of the fleet.
    fn num_splitters(&self) -> usize;
    /// Columns each splitter statically owns (for routing).
    fn columns_of(&self, splitter: usize) -> Vec<usize>;
    /// Begin `tree` on every splitter (resets its per-tree state).
    fn start_tree(&self, tree: u32) -> Result<()>;
    /// One splitter's bagged per-class counts at the root of `tree`.
    fn root_stats(&self, splitter: usize, tree: u32) -> Result<Vec<u64>>;
    /// Alg. 1 supersplit search on one splitter's columns.
    fn find_splits(&self, splitter: usize, q: &SupersplitQuery) -> Result<PartialSupersplit>;
    /// Evaluate chosen split conditions on the splitter that owns them.
    fn eval_conditions(&self, splitter: usize, q: &EvalQuery) -> Result<EvalResult>;
    /// Broadcast the level update to every splitter (the `Dn` bits).
    fn broadcast_level_update(&self, u: &LevelUpdate) -> Result<()>;
    /// Extract in-bag rows of detached leaves from one splitter's
    /// columns (depth-next growth; [`MaterializeQuery::want_meta`]
    /// additionally fetches labels + bag weights).
    fn materialize(&self, splitter: usize, q: &MaterializeQuery) -> Result<MaterializedLeaves>;
    /// Tell every splitter a resident subtree finished growing on the
    /// builder (observability; the class list already dropped those
    /// rows at detach time).
    fn broadcast_subtree_done(&self, d: &SubtreeDone) -> Result<()>;
    /// Drop `tree`'s state on every splitter.
    fn finish_tree(&self, tree: u32) -> Result<()>;
    /// Shared network counters.
    fn net_stats(&self) -> IoStats;

    // Single-splitter control RPCs. The tree builder only ever uses the
    // broadcast forms above; these exist so replay-based recovery
    // ([`super::recovery::RecoveringPool`]) can rebuild ONE splitter's
    // per-tree state over any transport — in-process or TCP — without
    // touching the rest of the fleet.

    /// Begin `tree` on a single splitter (recovery replay).
    fn start_tree_on(&self, splitter: usize, tree: u32) -> Result<()>;
    /// Apply one level update on a single splitter (recovery replay).
    fn apply_level_update_on(&self, splitter: usize, u: &LevelUpdate) -> Result<()>;
    /// Drop `tree`'s state on a single splitter (failure injection /
    /// cleanup).
    fn finish_tree_on(&self, splitter: usize, tree: u32) -> Result<()>;
    /// Notify one splitter of a finished resident subtree (recovery
    /// re-notification after replay).
    fn broadcast_subtree_done_on(&self, splitter: usize, d: &SubtreeDone) -> Result<()>;
}

/// In-process pool: direct calls + byte accounting + optional latency.
pub struct DirectPool {
    splitters: Vec<Arc<SplitterCore>>,
    net: IoStats,
    latency: std::time::Duration,
}

impl DirectPool {
    pub fn new(splitters: Vec<Arc<SplitterCore>>, latency_us: u64) -> Self {
        Self {
            splitters,
            net: IoStats::new(),
            latency: std::time::Duration::from_micros(latency_us),
        }
    }

    pub fn splitter(&self, s: usize) -> &Arc<SplitterCore> {
        &self.splitters[s]
    }

    fn delay(&self) {
        if !self.latency.is_zero() {
            std::thread::sleep(self.latency);
        }
    }
}

impl SplitterPool for DirectPool {
    fn num_splitters(&self) -> usize {
        self.splitters.len()
    }

    fn columns_of(&self, splitter: usize) -> Vec<usize> {
        self.splitters[splitter].columns_owned()
    }

    fn start_tree(&self, tree: u32) -> Result<()> {
        // One tiny control message per splitter.
        self.net.add_broadcast(8, self.splitters.len() as u64);
        for s in &self.splitters {
            s.start_tree(tree);
        }
        Ok(())
    }

    fn root_stats(&self, splitter: usize, tree: u32) -> Result<Vec<u64>> {
        self.delay();
        self.net.add_net(8); // request
        let stats = self.splitters[splitter].root_stats(tree);
        self.net.add_net(stats.len() as u64 * 8); // response
        Ok(stats)
    }

    fn find_splits(&self, splitter: usize, q: &SupersplitQuery) -> Result<PartialSupersplit> {
        self.delay();
        self.net.add_net(q.wire_bytes());
        let p = self.splitters[splitter].find_splits(q)?;
        self.net.add_net(p.wire_bytes());
        Ok(p)
    }

    fn eval_conditions(&self, splitter: usize, q: &EvalQuery) -> Result<EvalResult> {
        self.delay();
        self.net.add_net(q.wire_bytes());
        let r = self.splitters[splitter].eval_conditions(q)?;
        self.net.add_net(r.wire_bytes());
        Ok(r)
    }

    fn broadcast_level_update(&self, u: &LevelUpdate) -> Result<()> {
        self.delay();
        // The paper's "Dn bits in D allreduce": one bit per live sample,
        // broadcast to every splitter.
        self.net
            .add_broadcast(u.wire_bytes(), self.splitters.len() as u64);
        for s in &self.splitters {
            s.apply_level_update(u)?;
        }
        Ok(())
    }

    fn materialize(&self, splitter: usize, q: &MaterializeQuery) -> Result<MaterializedLeaves> {
        self.delay();
        self.net.add_net(q.wire_bytes());
        let m = self.splitters[splitter].materialize(q)?;
        self.net.add_net(m.wire_bytes());
        Ok(m)
    }

    fn broadcast_subtree_done(&self, d: &SubtreeDone) -> Result<()> {
        self.delay();
        self.net
            .add_broadcast(d.wire_bytes(), self.splitters.len() as u64);
        for s in &self.splitters {
            s.subtree_done(d)?;
        }
        Ok(())
    }

    fn finish_tree(&self, tree: u32) -> Result<()> {
        self.net.add_broadcast(8, self.splitters.len() as u64);
        for s in &self.splitters {
            s.finish_tree(tree);
        }
        Ok(())
    }

    fn net_stats(&self) -> IoStats {
        self.net.clone()
    }

    fn start_tree_on(&self, splitter: usize, tree: u32) -> Result<()> {
        self.net.add_net(8);
        self.splitters[splitter].start_tree(tree);
        Ok(())
    }

    fn apply_level_update_on(&self, splitter: usize, u: &LevelUpdate) -> Result<()> {
        self.net.add_net(u.wire_bytes());
        self.splitters[splitter].apply_level_update(u)
    }

    fn finish_tree_on(&self, splitter: usize, tree: u32) -> Result<()> {
        self.net.add_net(8);
        self.splitters[splitter].finish_tree(tree);
        Ok(())
    }

    fn broadcast_subtree_done_on(&self, splitter: usize, d: &SubtreeDone) -> Result<()> {
        self.net.add_net(d.wire_bytes());
        self.splitters[splitter].subtree_done(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PruneMode, SplitSearch};
    use crate::coordinator::splitter::{memory_storage_for, SplitterConfig};
    use crate::data::synthetic::{Family, SyntheticSpec};
    use crate::rng::{Bagger, BaggingMode, FeatureSampling};
    use crate::splits::scorer::ScoreKind;

    fn pool() -> DirectPool {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 50, 4, 1).generate();
        let labels = Arc::new(ds.labels().to_vec());
        let cfg = SplitterConfig {
            seed: 1,
            bagger: Bagger::new(1, BaggingMode::None),
            feature_sampling: FeatureSampling::All,
            num_candidates: 4,
            score_kind: ScoreKind::Gini,
            prune: PruneMode::Never,
            scan_threads: 1,
            split_search: SplitSearch::Exact,
        };
        let splitters = (0..2)
            .map(|s| {
                let cols: Vec<usize> = (0..4).filter(|j| j % 2 == s).collect();
                Arc::new(SplitterCore::new(
                    s,
                    ds.schema().clone(),
                    memory_storage_for(&ds, &cols),
                    labels.clone(),
                    cfg,
                    IoStats::new(),
                ))
            })
            .collect();
        DirectPool::new(splitters, 0)
    }

    #[test]
    fn accounting_charges_both_directions() {
        let p = pool();
        p.start_tree(0).unwrap();
        let before = p.net_stats().net_bytes();
        let stats = p.root_stats(0, 0).unwrap();
        assert_eq!(stats.iter().sum::<u64>(), 50);
        let after = p.net_stats().net_bytes();
        assert_eq!(after - before, 8 + 16, "8B request + 2x8B histogram");
    }

    #[test]
    fn broadcast_fanout_charged() {
        let p = pool();
        p.start_tree(0).unwrap();
        let u = LevelUpdate {
            tree: 0,
            depth: 0,
            outcomes: vec![super::super::messages::LeafOutcome::Closed],
        };
        let before = p.net_stats().snapshot();
        p.broadcast_level_update(&u).unwrap();
        let d = p.net_stats().snapshot().delta_since(&before);
        assert_eq!(d.net_bytes, u.wire_bytes() * 2, "2 splitters");
        assert_eq!(d.net_broadcasts, 1);
    }

    #[test]
    fn columns_routing() {
        let p = pool();
        assert_eq!(p.columns_of(0), vec![0, 2]);
        assert_eq!(p.columns_of(1), vec![1, 3]);
    }
}
