//! Binary wire codec for the coordinator protocol.
//!
//! Length-prefixed frames with a compact little-endian encoding; this
//! is what actually crosses sockets in the TCP engine
//! ([`super::tcp`]), and its sizes are what the `wire_bytes()`
//! estimates in [`super::messages`] model. Round-trip fidelity is
//! property-tested in `rust/tests/property.rs`-style unit tests below.

use super::messages::{
    Bitmap, EvalQuery, EvalResult, LeafInfo, LeafOutcome, LevelUpdate, MaterializeQuery,
    MaterializedColumn, MaterializedLeaf, MaterializedLeaves, PartialSupersplit, SubtreeDone,
    SupersplitQuery,
};
use crate::splits::SplitCandidate;
use crate::telemetry::{TimeSyncReply, TraceContext};
use crate::tree::{CategorySet, Condition};
use crate::util::wire::{get_trace_context, put_trace_context};
use crate::Result;
use anyhow::{bail, ensure, Context};

// The writer/reader scalars and frame helpers are the shared wire
// substrate ([`crate::util::wire`]); re-exported here because this
// module historically defined them and the TCP engine + serving codec
// import them from this path.
pub use crate::util::wire::{read_frame, write_frame, Reader, Writer};

// ---------------------------------------------------------------------
// Message encodings
// ---------------------------------------------------------------------

fn put_condition(w: &mut Writer, c: &Condition) {
    match c {
        Condition::NumLe { feature, threshold } => {
            w.u8(0);
            w.usize_u32(*feature);
            w.f32(*threshold);
        }
        Condition::CatIn { feature, set } => {
            w.u8(1);
            w.usize_u32(*feature);
            w.u32(set.arity());
            let values: Vec<u32> = set.iter().collect();
            w.usize_u32(values.len());
            for v in values {
                w.u32(v);
            }
        }
    }
}

/// Dense-bitset allocation budget for the `CatIn` conditions of one
/// frame. A [`CategorySet`] allocates `⌈arity/64⌉` words no matter how
/// few members the wire lists, so a small frame forging `arity =
/// u32::MAX` would otherwise cost 512 MiB per condition (fuzz finding).
/// The budget scales with the frame (a frame legitimately carrying many
/// member values may carry proportionally large sets) plus a constant
/// floor that admits sparse sets over high-arity columns (~4M
/// categories) from even the smallest frame.
struct ConditionBudget {
    left: u64,
}

impl ConditionBudget {
    fn new(frame_len: usize) -> Self {
        Self {
            left: 64 * frame_len as u64 + (1 << 19),
        }
    }

    fn charge(&mut self, arity: u32) -> Result<()> {
        let bytes = (arity as u64).div_ceil(64) * 8;
        ensure!(
            bytes <= self.left,
            "categorical conditions exceed the frame's allocation budget \
             (arity {arity} wants {bytes} more bytes)"
        );
        self.left -= bytes;
        Ok(())
    }
}

fn get_condition(r: &mut Reader<'_>, budget: &mut ConditionBudget) -> Result<Condition> {
    Ok(match r.u8()? {
        0 => Condition::NumLe {
            feature: r.u32()? as usize,
            threshold: r.f32()?,
        },
        1 => {
            let feature = r.u32()? as usize;
            let arity = r.u32()?;
            budget.charge(arity)?;
            let n = r.len_checked(4)?;
            let values: Vec<u32> = (0..n).map(|_| r.u32()).collect::<Result<_>>()?;
            // Members must lie inside the declared support —
            // `CategorySet::insert` indexes its words unchecked (fuzz
            // finding: a wire value ≥ arity was an out-of-bounds write
            // target in release builds).
            if let Some(&v) = values.iter().find(|&&v| v >= arity) {
                bail!("categorical condition value {v} >= arity {arity}");
            }
            Condition::CatIn {
                feature,
                set: CategorySet::from_values(arity, values),
            }
        }
        t => bail!("bad condition tag {t}"),
    })
}

fn put_bitmap(w: &mut Writer, b: &Bitmap) {
    w.usize_u32(b.len());
    // Pack 8 bits per byte.
    let mut byte = 0u8;
    for i in 0..b.len() {
        if b.get(i) {
            byte |= 1 << (i % 8);
        }
        if i % 8 == 7 {
            w.u8(byte);
            byte = 0;
        }
    }
    if b.len() % 8 != 0 {
        w.u8(byte);
    }
}

fn get_bitmap(r: &mut Reader<'_>) -> Result<Bitmap> {
    let len = r.len_u32()?;
    let mut b = Bitmap::with_len(len);
    let bytes = r.take(len.div_ceil(8))?;
    for i in 0..len {
        if (bytes[i / 8] >> (i % 8)) & 1 == 1 {
            b.set(i, true);
        }
    }
    Ok(b)
}

fn put_candidate(w: &mut Writer, c: &SplitCandidate) {
    put_condition(w, &c.condition);
    w.f64(c.gain);
    w.u64_slice(&c.left_counts);
    w.u64_slice(&c.right_counts);
}

fn get_candidate(r: &mut Reader<'_>, budget: &mut ConditionBudget) -> Result<SplitCandidate> {
    Ok(SplitCandidate {
        condition: get_condition(r, budget)?,
        gain: r.f64()?,
        left_counts: r.u64_vec()?,
        right_counts: r.u64_vec()?,
    })
}

/// Version of the splitter RPC protocol. Bumped on any wire change;
/// exchanged in the Hello handshake so a leader and a standalone worker
/// from different builds fail fast instead of mis-decoding frames.
/// v3 added the `TimeSync` RPC and the optional trace-context request
/// trailer — both backward-decodable (a context-free v3 frame is
/// byte-identical to v2), but negotiated in Hello all the same so a
/// mixed fleet fails fast rather than dropping trace context silently.
/// v4 added `topology_version` to the Hello handshake — the cluster
/// manifest generation a leader trains against, so a worker can accept
/// an elastic re-shard (newer version, reload the pack manifest) and
/// refuse a stale leader (older version) instead of requiring an
/// exact-match config.
pub const PROTOCOL_VERSION: u32 = 4;

/// Leader → worker handshake. Identifies the protocol and shard the
/// leader expects on this connection and carries the training
/// configuration a standalone worker needs to build its splitter core
/// (enums travel as their canonical `as_str` names). In-process
/// splitter servers already hold a configured core and only validate
/// and answer.
#[derive(Debug, Clone, PartialEq)]
pub struct HelloConfig {
    pub protocol: u32,
    /// Splitter / shard id this connection is for.
    pub shard: u32,
    /// Topology the shard packs were cut for; a worker refuses a
    /// mismatch (a pack is only valid for its own ownership map).
    pub num_splitters: u32,
    pub redundancy: u32,
    pub seed: u64,
    pub bagging: String,
    pub sampling: String,
    pub num_candidates: u32,
    pub score_kind: String,
    /// SPRINT prune threshold (`None` = never prune).
    pub prune_threshold: Option<f64>,
    /// Split search strategy (`"exact"` or `"mab"`); the worker builds
    /// its splitter core with the same strategy as the leader so the
    /// fleet agrees on which scan schedule runs.
    pub split_search: String,
    /// Depth-next cache budget the leader trains with; carried so a
    /// worker can log/validate the full training config (the schedule
    /// itself is driven entirely by the leader's tree builder).
    pub depth_next_rows: u64,
    /// Cluster-manifest generation the leader read its topology from
    /// (`ClusterManifest::version`; 0 for the initial cut and for
    /// engines with no manifest). A worker holding an older manifest
    /// reloads it from its shard source before answering; a Hello
    /// *older* than what the worker already serves is refused — it
    /// would mean a stale leader driving a re-sharded fleet.
    pub topology_version: u64,
}

/// Worker → leader handshake answer: the worker's actual inventory, so
/// the leader can validate the whole fleet before training starts.
#[derive(Debug, Clone, PartialEq)]
pub struct HelloInfo {
    pub protocol: u32,
    pub shard: u32,
    pub rows: u64,
    pub num_classes: u32,
    /// Column indices the worker's shard pack holds, ascending.
    pub columns: Vec<u32>,
}

/// The RPC request frame body.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    StartTree(u32),
    RootStats(u32),
    FindSplits(SupersplitQuery),
    EvalConditions(EvalQuery),
    LevelUpdate(LevelUpdate),
    FinishTree(u32),
    Shutdown,
    Hello(HelloConfig),
    /// Extract the in-bag rows of detached leaves (depth-next growth).
    Materialize(MaterializeQuery),
    /// A depth-first resident subtree finished on the builder.
    SubtreeDone(SubtreeDone),
    /// Ask the peer for its trace clock + identity (clock alignment).
    TimeSync,
}

/// The RPC response frame body.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok,
    RootStats(Vec<u64>),
    Splits(PartialSupersplit),
    Evals(EvalResult),
    Err(String),
    Hello(HelloInfo),
    /// Answer to [`Request::Materialize`].
    Materialized(MaterializedLeaves),
    /// Answer to [`Request::TimeSync`].
    TimeSync(TimeSyncReply),
}

/// Encode a [`TimeSyncReply`] (shared by request/response codecs that
/// carry one).
pub fn put_time_sync(w: &mut Writer, t: &TimeSyncReply) {
    w.str(&t.role);
    match t.shard {
        None => w.bool(false),
        Some(s) => {
            w.bool(true);
            w.u64(s);
        }
    }
    w.u64(t.pid);
    w.u64(t.t_us);
}

/// Decode a [`TimeSyncReply`].
pub fn get_time_sync(r: &mut Reader<'_>) -> Result<TimeSyncReply> {
    Ok(TimeSyncReply {
        role: r.str()?,
        shard: if r.bool()? { Some(r.u64()?) } else { None },
        pid: r.u64()?,
        t_us: r.u64()?,
    })
}

/// Encode a request with no trace context — byte-identical to the v2
/// encoding for every v2 message.
pub fn encode_request(req: &Request) -> Vec<u8> {
    encode_request_traced(req, None)
}

/// Encode a request, appending the optional trace-context trailer so
/// the callee's spans can parent under the caller's current span.
pub fn encode_request_traced(req: &Request, ctx: Option<&TraceContext>) -> Vec<u8> {
    let mut w = Writer::new();
    encode_request_body(&mut w, req);
    put_trace_context(&mut w, ctx);
    w.into_bytes()
}

fn encode_request_body(w: &mut Writer, req: &Request) {
    match req {
        Request::StartTree(t) => {
            w.u8(0);
            w.u32(*t);
        }
        Request::RootStats(t) => {
            w.u8(1);
            w.u32(*t);
        }
        Request::FindSplits(q) => {
            w.u8(2);
            w.u32(q.tree);
            w.u32(q.depth);
            w.usize_u32(q.leaves.len());
            for l in &q.leaves {
                w.u32(l.node_id);
                w.bool(l.detached);
                w.u64_slice(&l.totals);
            }
            w.usize_u32(q.assigned_columns.len());
            for &c in &q.assigned_columns {
                w.usize_u32(c);
            }
        }
        Request::EvalConditions(q) => {
            w.u8(3);
            w.u32(q.tree);
            w.u32(q.depth);
            w.usize_u32(q.conditions.len());
            for (rank, cond) in &q.conditions {
                w.u32(*rank);
                put_condition(&mut w, cond);
            }
        }
        Request::LevelUpdate(u) => {
            w.u8(4);
            w.u32(u.tree);
            w.u32(u.depth);
            w.usize_u32(u.outcomes.len());
            for o in &u.outcomes {
                match o {
                    LeafOutcome::Closed => w.u8(0),
                    LeafOutcome::Split {
                        bitmap,
                        left_open,
                        right_open,
                    } => {
                        w.u8(1);
                        put_bitmap(&mut w, bitmap);
                        w.bool(*left_open);
                        w.bool(*right_open);
                    }
                    LeafOutcome::Detached => w.u8(2),
                }
            }
        }
        Request::FinishTree(t) => {
            w.u8(5);
            w.u32(*t);
        }
        Request::Shutdown => w.u8(6),
        Request::Hello(h) => {
            w.u8(7);
            w.u32(h.protocol);
            w.u32(h.shard);
            w.u32(h.num_splitters);
            w.u32(h.redundancy);
            w.u64(h.seed);
            w.str(&h.bagging);
            w.str(&h.sampling);
            w.u32(h.num_candidates);
            w.str(&h.score_kind);
            match h.prune_threshold {
                None => w.bool(false),
                Some(t) => {
                    w.bool(true);
                    w.f64(t);
                }
            }
            w.str(&h.split_search);
            w.u64(h.depth_next_rows);
            w.u64(h.topology_version);
        }
        Request::Materialize(q) => {
            w.u8(8);
            w.u32(q.tree);
            w.u32(q.depth);
            w.bool(q.want_meta);
            w.usize_u32(q.ranks.len());
            for &rank in &q.ranks {
                w.u32(rank);
            }
            w.usize_u32(q.columns.len());
            for &c in &q.columns {
                w.usize_u32(c);
            }
        }
        Request::SubtreeDone(d) => {
            w.u8(9);
            w.u32(d.tree);
            w.u32(d.root);
            w.u64(d.rows);
            w.u32(d.nodes);
        }
        Request::TimeSync => w.u8(10),
    }
}

/// Decode a request, discarding any trace context (in-process servers
/// that never re-export context use this).
pub fn decode_request(buf: &[u8]) -> Result<Request> {
    Ok(decode_request_traced(buf)?.0)
}

/// Decode a request plus its optional trace-context trailer. A
/// context-free (v2-style) frame decodes to `(req, None)`.
pub fn decode_request_traced(buf: &[u8]) -> Result<(Request, Option<TraceContext>)> {
    let mut r = Reader::new(buf);
    let mut budget = ConditionBudget::new(buf.len());
    let req = decode_request_body(&mut r, &mut budget)?;
    let ctx = get_trace_context(&mut r)?;
    r.done()?;
    Ok((req, ctx))
}

fn decode_request_body(r: &mut Reader<'_>, budget: &mut ConditionBudget) -> Result<Request> {
    let req = match r.u8().context("empty request frame")? {
        0 => Request::StartTree(r.u32()?),
        1 => Request::RootStats(r.u32()?),
        2 => {
            let tree = r.u32()?;
            let depth = r.u32()?;
            // A leaf is at least node_id + detached + totals-prefix on
            // the wire; a forged count cannot outrun the frame.
            let nl = r.len_checked(9)?;
            let leaves = (0..nl)
                .map(|_| {
                    Ok(LeafInfo {
                        node_id: r.u32()?,
                        detached: r.bool()?,
                        totals: r.u64_vec()?,
                    })
                })
                .collect::<Result<_>>()?;
            let nc = r.len_checked(4)?;
            let assigned_columns = (0..nc)
                .map(|_| Ok(r.u32()? as usize))
                .collect::<Result<_>>()?;
            Request::FindSplits(SupersplitQuery {
                tree,
                depth,
                leaves,
                assigned_columns,
            })
        }
        3 => {
            let tree = r.u32()?;
            let depth = r.u32()?;
            // Rank + the smallest condition (NumLe) is 13 wire bytes.
            let n = r.len_checked(13)?;
            let conditions = (0..n)
                .map(|_| Ok((r.u32()?, get_condition(r, budget)?)))
                .collect::<Result<_>>()?;
            Request::EvalConditions(EvalQuery {
                tree,
                depth,
                conditions,
            })
        }
        4 => {
            let tree = r.u32()?;
            let depth = r.u32()?;
            let n = r.len_checked(1)?;
            let outcomes = (0..n)
                .map(|_| {
                    Ok(match r.u8()? {
                        0 => LeafOutcome::Closed,
                        1 => LeafOutcome::Split {
                            bitmap: get_bitmap(r)?,
                            left_open: r.bool()?,
                            right_open: r.bool()?,
                        },
                        2 => LeafOutcome::Detached,
                        t => bail!("bad outcome tag {t}"),
                    })
                })
                .collect::<Result<_>>()?;
            Request::LevelUpdate(LevelUpdate {
                tree,
                depth,
                outcomes,
            })
        }
        5 => Request::FinishTree(r.u32()?),
        6 => Request::Shutdown,
        7 => {
            let protocol = r.u32()?;
            let shard = r.u32()?;
            let num_splitters = r.u32()?;
            let redundancy = r.u32()?;
            let seed = r.u64()?;
            let bagging = r.str()?;
            let sampling = r.str()?;
            let num_candidates = r.u32()?;
            let score_kind = r.str()?;
            let prune_threshold = if r.bool()? { Some(r.f64()?) } else { None };
            let split_search = r.str()?;
            let depth_next_rows = r.u64()?;
            let topology_version = r.u64()?;
            Request::Hello(HelloConfig {
                protocol,
                shard,
                num_splitters,
                redundancy,
                seed,
                bagging,
                sampling,
                num_candidates,
                score_kind,
                prune_threshold,
                split_search,
                depth_next_rows,
                topology_version,
            })
        }
        8 => {
            let tree = r.u32()?;
            let depth = r.u32()?;
            let want_meta = r.bool()?;
            let nr = r.len_checked(4)?;
            let ranks = (0..nr).map(|_| r.u32()).collect::<Result<_>>()?;
            let nc = r.len_checked(4)?;
            let columns = (0..nc)
                .map(|_| Ok(r.u32()? as usize))
                .collect::<Result<_>>()?;
            Request::Materialize(MaterializeQuery {
                tree,
                depth,
                ranks,
                columns,
                want_meta,
            })
        }
        9 => Request::SubtreeDone(SubtreeDone {
            tree: r.u32()?,
            root: r.u32()?,
            rows: r.u64()?,
            nodes: r.u32()?,
        }),
        10 => Request::TimeSync,
        t => bail!("bad request tag {t}"),
    };
    Ok(req)
}

pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut w = Writer::new();
    match resp {
        Response::Ok => w.u8(0),
        Response::RootStats(v) => {
            w.u8(1);
            w.u64_slice(v);
        }
        Response::Splits(p) => {
            w.u8(2);
            w.usize_u32(p.splits.len());
            for s in &p.splits {
                match s {
                    None => w.u8(0),
                    Some(c) => {
                        w.u8(1);
                        put_candidate(&mut w, c);
                    }
                }
            }
        }
        Response::Evals(e) => {
            w.u8(3);
            w.usize_u32(e.bitmaps.len());
            for (rank, b) in &e.bitmaps {
                w.u32(*rank);
                put_bitmap(&mut w, b);
            }
        }
        Response::Err(msg) => {
            w.u8(4);
            w.str(msg);
        }
        Response::Hello(i) => {
            w.u8(5);
            w.u32(i.protocol);
            w.u32(i.shard);
            w.u64(i.rows);
            w.u32(i.num_classes);
            w.usize_u32(i.columns.len());
            for &c in &i.columns {
                w.u32(c);
            }
        }
        Response::Materialized(m) => {
            w.u8(6);
            w.usize_u32(m.leaves.len());
            for leaf in &m.leaves {
                w.u64(leaf.rows);
                w.usize_u32(leaf.labels.len());
                for &l in &leaf.labels {
                    w.u32(l);
                }
                w.usize_u32(leaf.bags.len());
                for &b in &leaf.bags {
                    w.u8(b);
                }
                w.usize_u32(leaf.columns.len());
                for col in &leaf.columns {
                    match col {
                        MaterializedColumn::Num(values) => {
                            w.u8(0);
                            w.usize_u32(values.len());
                            for &v in values {
                                w.f32(v);
                            }
                        }
                        MaterializedColumn::Cat { arity, values } => {
                            w.u8(1);
                            w.u32(*arity);
                            w.usize_u32(values.len());
                            for &v in values {
                                w.u32(v);
                            }
                        }
                    }
                }
            }
        }
        Response::TimeSync(t) => {
            w.u8(7);
            put_time_sync(&mut w, t);
        }
    }
    w.into_bytes()
}

pub fn decode_response(buf: &[u8]) -> Result<Response> {
    let mut r = Reader::new(buf);
    let mut budget = ConditionBudget::new(buf.len());
    let resp = match r.u8().context("empty response frame")? {
        0 => Response::Ok,
        1 => Response::RootStats(r.u64_vec()?),
        2 => {
            let n = r.len_checked(1)?;
            let splits = (0..n)
                .map(|_| {
                    Ok(match r.u8()? {
                        0 => None,
                        1 => Some(get_candidate(&mut r, &mut budget)?),
                        t => bail!("bad option tag {t}"),
                    })
                })
                .collect::<Result<_>>()?;
            Response::Splits(PartialSupersplit { splits })
        }
        3 => {
            // Rank + bitmap length prefix is 8 wire bytes minimum.
            let n = r.len_checked(8)?;
            let bitmaps = (0..n)
                .map(|_| Ok((r.u32()?, get_bitmap(&mut r)?)))
                .collect::<Result<_>>()?;
            Response::Evals(EvalResult { bitmaps })
        }
        4 => Response::Err(r.str()?),
        5 => {
            let protocol = r.u32()?;
            let shard = r.u32()?;
            let rows = r.u64()?;
            let num_classes = r.u32()?;
            let n = r.len_checked(4)?;
            let columns = (0..n).map(|_| r.u32()).collect::<Result<_>>()?;
            Response::Hello(HelloInfo {
                protocol,
                shard,
                rows,
                num_classes,
                columns,
            })
        }
        6 => {
            // A leaf is at least rows + three length prefixes (20 B).
            let nl = r.len_checked(20)?;
            let leaves = (0..nl)
                .map(|_| {
                    let rows = r.u64()?;
                    let n = r.len_checked(4)?;
                    let labels = (0..n).map(|_| r.u32()).collect::<Result<_>>()?;
                    let nb = r.len_checked(1)?;
                    let bags = r.take(nb)?.to_vec();
                    // A materialized column is at least tag + length
                    // prefix (5 B).
                    let nc = r.len_checked(5)?;
                    let columns = (0..nc)
                        .map(|_| {
                            Ok(match r.u8()? {
                                0 => {
                                    let nv = r.len_checked(4)?;
                                    MaterializedColumn::Num(
                                        (0..nv).map(|_| r.f32()).collect::<Result<_>>()?,
                                    )
                                }
                                1 => {
                                    let arity = r.u32()?;
                                    let nv = r.len_checked(4)?;
                                    MaterializedColumn::Cat {
                                        arity,
                                        values: (0..nv).map(|_| r.u32()).collect::<Result<_>>()?,
                                    }
                                }
                                t => bail!("bad column tag {t}"),
                            })
                        })
                        .collect::<Result<_>>()?;
                    Ok(MaterializedLeaf {
                        rows,
                        labels,
                        bags,
                        columns,
                    })
                })
                .collect::<Result<_>>()?;
            Response::Materialized(MaterializedLeaves { leaves })
        }
        7 => Response::TimeSync(get_time_sync(&mut r)?),
        t => bail!("bad response tag {t}"),
    };
    r.done()?;
    Ok(resp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{run_cases, CaseRng};

    fn random_condition(rng: &mut CaseRng) -> Condition {
        if rng.bool(0.5) {
            Condition::NumLe {
                feature: rng.usize(0, 100),
                threshold: rng.f32() * 10.0 - 5.0,
            }
        } else {
            let arity = rng.usize(1, 200) as u32;
            let vals: Vec<u32> = (0..rng.usize(0, 10))
                .map(|_| rng.u64(arity as u64) as u32)
                .collect();
            Condition::CatIn {
                feature: rng.usize(0, 100),
                set: CategorySet::from_values(arity, vals),
            }
        }
    }

    fn random_bitmap(rng: &mut CaseRng) -> Bitmap {
        let n = rng.usize(0, 200);
        let mut b = Bitmap::with_len(n);
        for i in 0..n {
            if rng.bool(0.5) {
                b.set(i, true);
            }
        }
        b
    }

    #[test]
    fn request_roundtrip_random() {
        run_cases(0x31E, 40, |rng| {
            let req = match rng.usize(0, 8) {
                0 => Request::StartTree(rng.u64(1000) as u32),
                1 => Request::RootStats(rng.u64(1000) as u32),
                2 => Request::FindSplits(SupersplitQuery {
                    tree: rng.u64(100) as u32,
                    depth: rng.u64(30) as u32,
                    leaves: (0..rng.usize(0, 6))
                        .map(|_| LeafInfo {
                            node_id: rng.u64(1000) as u32,
                            detached: rng.bool(0.2),
                            totals: (0..rng.usize(1, 4)).map(|_| rng.u64(1 << 40)).collect(),
                        })
                        .collect(),
                    assigned_columns: (0..rng.usize(0, 8)).map(|_| rng.usize(0, 99)).collect(),
                }),
                3 => Request::EvalConditions(EvalQuery {
                    tree: rng.u64(100) as u32,
                    depth: rng.u64(30) as u32,
                    conditions: (0..rng.usize(0, 5))
                        .map(|_| (rng.u64(64) as u32 + 1, random_condition(rng)))
                        .collect(),
                }),
                4 => Request::LevelUpdate(LevelUpdate {
                    tree: rng.u64(100) as u32,
                    depth: rng.u64(30) as u32,
                    outcomes: (0..rng.usize(0, 5))
                        .map(|_| {
                            if rng.bool(0.3) {
                                LeafOutcome::Closed
                            } else if rng.bool(0.2) {
                                LeafOutcome::Detached
                            } else {
                                LeafOutcome::Split {
                                    bitmap: random_bitmap(rng),
                                    left_open: rng.bool(0.8),
                                    right_open: rng.bool(0.8),
                                }
                            }
                        })
                        .collect(),
                }),
                5 => Request::Materialize(MaterializeQuery {
                    tree: rng.u64(100) as u32,
                    depth: rng.u64(30) as u32,
                    ranks: (0..rng.usize(0, 6)).map(|_| rng.u64(64) as u32).collect(),
                    columns: (0..rng.usize(0, 8)).map(|_| rng.usize(0, 99)).collect(),
                    want_meta: rng.bool(0.5),
                }),
                6 => Request::SubtreeDone(SubtreeDone {
                    tree: rng.u64(100) as u32,
                    root: rng.u64(1000) as u32,
                    rows: rng.u64(1 << 40),
                    nodes: rng.u64(1000) as u32,
                }),
                7 => Request::TimeSync,
                _ => Request::FinishTree(rng.u64(1000) as u32),
            };
            let bytes = encode_request(&req);
            let back = decode_request(&bytes).unwrap();
            assert_eq!(req, back);
            // The traced codec round-trips the same message with its
            // context trailer, whatever the body is.
            let ctx = TraceContext {
                trace_id: rng.u64(1 << 52).max(1),
                parent_span: rng.u64(1 << 52),
            };
            let traced = encode_request_traced(&req, Some(&ctx));
            assert_eq!(traced.len(), bytes.len() + 16);
            assert_eq!(decode_request_traced(&traced).unwrap(), (req, Some(ctx)));
        });
    }

    #[test]
    fn response_roundtrip_random() {
        run_cases(0x52E, 40, |rng| {
            let resp = match rng.usize(0, 6) {
                0 => Response::Ok,
                1 => Response::RootStats(
                    (0..rng.usize(0, 5)).map(|_| rng.u64(1 << 50)).collect(),
                ),
                2 => Response::Splits(PartialSupersplit {
                    splits: (0..rng.usize(0, 5))
                        .map(|_| {
                            rng.bool(0.5).then(|| SplitCandidate {
                                condition: random_condition(rng),
                                gain: rng.f64(),
                                left_counts: vec![rng.u64(100), rng.u64(100)],
                                right_counts: vec![rng.u64(100), rng.u64(100)],
                            })
                        })
                        .collect(),
                }),
                3 => Response::Evals(EvalResult {
                    bitmaps: (0..rng.usize(0, 4))
                        .map(|_| (rng.u64(64) as u32 + 1, random_bitmap(rng)))
                        .collect(),
                }),
                4 => Response::Materialized(MaterializedLeaves {
                    leaves: (0..rng.usize(0, 3))
                        .map(|_| {
                            let n = rng.usize(0, 6);
                            MaterializedLeaf {
                                rows: n as u64,
                                labels: (0..n).map(|_| rng.u64(5) as u32).collect(),
                                bags: (0..n).map(|_| rng.u64(4) as u8).collect(),
                                columns: (0..rng.usize(0, 3))
                                    .map(|_| {
                                        if rng.bool(0.5) {
                                            MaterializedColumn::Num(
                                                (0..n).map(|_| rng.f32()).collect(),
                                            )
                                        } else {
                                            MaterializedColumn::Cat {
                                                arity: 7,
                                                values: (0..n)
                                                    .map(|_| rng.u64(7) as u32)
                                                    .collect(),
                                            }
                                        }
                                    })
                                    .collect(),
                            }
                        })
                        .collect(),
                }),
                5 => Response::TimeSync(TimeSyncReply {
                    role: if rng.bool(0.5) { "worker".into() } else { "objstore".into() },
                    shard: rng.bool(0.5).then(|| rng.u64(64)),
                    pid: rng.u64(1 << 22),
                    t_us: rng.u64(1 << 50),
                }),
                _ => Response::Err("splitter 3: unknown tree 7".into()),
            };
            let bytes = encode_response(&resp);
            let back = decode_response(&bytes).unwrap();
            assert_eq!(resp, back);
        });
    }

    #[test]
    fn corrupt_frames_rejected() {
        assert!(decode_request(&[]).is_err());
        assert!(decode_request(&[99]).is_err());
        assert!(decode_response(&[2, 1, 0, 0, 0]).is_err(), "truncated");
        // Trailing garbage.
        let mut bytes = encode_request(&Request::StartTree(1));
        bytes.push(0);
        assert!(decode_request(&bytes).is_err());
        // A torn context trailer (8 of 16 bytes) is also rejected.
        let mut bytes = encode_request(&Request::StartTree(1));
        bytes.extend_from_slice(&7u64.to_le_bytes());
        assert!(decode_request(&bytes).is_err());
    }

    #[test]
    fn trace_context_is_optional_and_context_free_frames_are_byte_identical() {
        let req = Request::FindSplits(SupersplitQuery {
            tree: 4,
            depth: 2,
            leaves: vec![LeafInfo {
                node_id: 9,
                detached: false,
                totals: vec![10, 20],
            }],
            assigned_columns: vec![0, 3],
        });
        // A context-free traced encoding is byte-for-byte the legacy
        // encoding: an old peer cannot tell the builds apart.
        assert_eq!(encode_request_traced(&req, None), encode_request(&req));
        // A context-free frame decodes through the traced decoder.
        let (back, ctx) = decode_request_traced(&encode_request(&req)).unwrap();
        assert_eq!(back, req);
        assert_eq!(ctx, None);
        // A traced frame round-trips its context...
        let c = TraceContext {
            trace_id: 0xA11CE,
            parent_span: 0xB0B,
        };
        let traced = encode_request_traced(&req, Some(&c));
        let (back, ctx) = decode_request_traced(&traced).unwrap();
        assert_eq!(back, req);
        assert_eq!(ctx, Some(c));
        // ...and the context-oblivious decoder still accepts it,
        // discarding the trailer (a worker serving a traced leader
        // without caring about context keeps working).
        assert_eq!(decode_request(&traced).unwrap(), req);
    }

    #[test]
    fn time_sync_roundtrip() {
        assert_eq!(
            decode_request(&encode_request(&Request::TimeSync)).unwrap(),
            Request::TimeSync
        );
        for shard in [None, Some(11u64)] {
            let resp = Response::TimeSync(TimeSyncReply {
                role: "worker".into(),
                shard,
                pid: 4242,
                t_us: 123_456_789,
            });
            assert_eq!(decode_response(&encode_response(&resp)).unwrap(), resp);
        }
    }

    #[test]
    fn hello_roundtrip() {
        let req = Request::Hello(HelloConfig {
            protocol: PROTOCOL_VERSION,
            shard: 3,
            num_splitters: 8,
            redundancy: 2,
            seed: 0xDEAD_BEEF_CAFE,
            bagging: "poisson".into(),
            sampling: "per_node".into(),
            num_candidates: 5,
            score_kind: "gini".into(),
            prune_threshold: Some(0.75),
            split_search: "mab".into(),
            depth_next_rows: 65536,
            topology_version: 9,
        });
        assert_eq!(decode_request(&encode_request(&req)).unwrap(), req);
        let req2 = Request::Hello(HelloConfig {
            protocol: PROTOCOL_VERSION,
            shard: 0,
            num_splitters: 1,
            redundancy: 1,
            seed: 7,
            bagging: "none".into(),
            sampling: "all".into(),
            num_candidates: 1,
            score_kind: "entropy".into(),
            prune_threshold: None,
            split_search: "exact".into(),
            depth_next_rows: 0,
            topology_version: 0,
        });
        assert_eq!(decode_request(&encode_request(&req2)).unwrap(), req2);
        let resp = Response::Hello(HelloInfo {
            protocol: PROTOCOL_VERSION,
            shard: 3,
            rows: 1 << 33,
            num_classes: 5,
            columns: vec![1, 4, 9],
        });
        assert_eq!(decode_response(&encode_response(&resp)).unwrap(), resp);
    }
}
