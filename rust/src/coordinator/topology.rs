//! Column → splitter ownership and per-level balanced assignment.
//!
//! The dataset is distributed per feature (paper §2.1): splitter `s`
//! owns columns `{j : j ≡ s (mod w)}`, and with redundancy `d` (§3.2)
//! each column is replicated on `d` distinct splitters. Per depth level,
//! the tree builder assigns each *candidate* column to exactly one of
//! its replicas using greedy least-loaded ("power of d choices", Azar et
//! al. 1999 — the paper's §3.2 shows this drops the per-worker load `Z`
//! from `log m''/log log m''` to `log log m''/log d`).

use crate::config::TopologyParams;
use std::collections::BTreeMap;

/// Static ownership map.
#[derive(Debug, Clone)]
pub struct Topology {
    num_splitters: usize,
    num_columns: usize,
    redundancy: usize,
    /// owners[j] = splitter ids that hold column j (length = redundancy).
    owners: Vec<Vec<usize>>,
}

impl Topology {
    pub fn new(num_columns: usize, params: &TopologyParams) -> Self {
        let num_splitters = params.splitters_for(num_columns);
        let redundancy = params.redundancy.min(num_splitters);
        let owners = (0..num_columns)
            .map(|j| {
                (0..redundancy)
                    .map(|k| (j + k * (num_columns / num_splitters + 1).max(1)) % num_splitters)
                    .fold(Vec::new(), |mut acc, s| {
                        // Ensure distinct owners even when the stride
                        // collides; linear-probe to the next free id.
                        let mut s = s;
                        while acc.contains(&s) {
                            s = (s + 1) % num_splitters;
                        }
                        acc.push(s);
                        acc
                    })
            })
            .collect();
        Self {
            num_splitters,
            num_columns,
            redundancy,
            owners,
        }
    }

    /// Build a topology from explicit per-splitter column lists (the
    /// cluster manifest's shard entries). After an elastic re-shard
    /// (`drf supervise --drain`) the ownership map is no longer the
    /// stride construction of [`Topology::new`], so the leader rebuilds
    /// it from what the manifest actually records. Splitters may own
    /// nothing (a drained slot); every column must be owned by at least
    /// one splitter. Owner lists come out sorted by splitter id, which
    /// [`Topology::assign_level`] is insensitive to (its argmin is over
    /// `(load, id)`, an order-independent key).
    pub fn from_owners(
        num_columns: usize,
        redundancy: usize,
        columns_per_splitter: &[Vec<usize>],
    ) -> crate::Result<Self> {
        let num_splitters = columns_per_splitter.len();
        let mut owners = vec![Vec::new(); num_columns];
        for (s, cols) in columns_per_splitter.iter().enumerate() {
            for &j in cols {
                anyhow::ensure!(
                    j < num_columns,
                    "splitter {s} claims column {j}, dataset has {num_columns}"
                );
                anyhow::ensure!(
                    !owners[j].contains(&s),
                    "splitter {s} lists column {j} twice"
                );
                owners[j].push(s);
            }
        }
        for (j, o) in owners.iter().enumerate() {
            anyhow::ensure!(!o.is_empty(), "column {j} has no owner");
        }
        Ok(Self {
            num_splitters,
            num_columns,
            redundancy,
            owners,
        })
    }

    pub fn num_splitters(&self) -> usize {
        self.num_splitters
    }

    pub fn num_columns(&self) -> usize {
        self.num_columns
    }

    pub fn redundancy(&self) -> usize {
        self.redundancy
    }

    /// Splitters holding column `j`.
    pub fn owners(&self, j: usize) -> &[usize] {
        &self.owners[j]
    }

    /// All columns held by splitter `s` (static shard, what the splitter
    /// loads at startup).
    pub fn columns_of(&self, s: usize) -> Vec<usize> {
        (0..self.num_columns)
            .filter(|&j| self.owners[j].contains(&s))
            .collect()
    }

    /// Per-level balanced assignment: map each candidate column to one
    /// replica, greedily least-loaded (ties to the lower splitter id —
    /// deterministic). Returns splitter → columns, and the max load `Z`.
    pub fn assign_level(&self, candidate_columns: &[usize]) -> LevelAssignment {
        let mut load = vec![0usize; self.num_splitters];
        let mut per_splitter: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        // Deterministic order: sorted unique columns.
        let mut cols: Vec<usize> = candidate_columns.to_vec();
        cols.sort_unstable();
        cols.dedup();
        for j in cols {
            let owners = &self.owners[j];
            let &best = owners
                .iter()
                .min_by_key(|&&s| (load[s], s))
                .expect("column has owners");
            load[best] += 1;
            per_splitter.entry(best).or_default().push(j);
        }
        let max_load = load.iter().copied().max().unwrap_or(0);
        LevelAssignment {
            per_splitter,
            max_load,
        }
    }
}

/// One level's column→splitter assignment.
#[derive(Debug, Clone)]
pub struct LevelAssignment {
    /// splitter id → columns it scans this level.
    pub per_splitter: BTreeMap<usize, Vec<usize>>,
    /// The level's `Z`: maximum columns assigned to one splitter.
    pub max_load: usize,
}

impl LevelAssignment {
    /// Which splitter was assigned column `j` this level?
    pub fn owner_of(&self, j: usize) -> Option<usize> {
        self.per_splitter
            .iter()
            .find(|(_, cols)| cols.contains(&j))
            .map(|(&s, _)| s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(w: Option<usize>, d: usize) -> TopologyParams {
        TopologyParams {
            num_splitters: w,
            redundancy: d,
            ..Default::default()
        }
    }

    #[test]
    fn every_column_owned_no_redundancy() {
        let t = Topology::new(10, &params(Some(3), 1));
        for j in 0..10 {
            assert_eq!(t.owners(j).len(), 1);
            assert!(t.owners(j)[0] < 3);
        }
        // Shards partition the columns.
        let all: usize = (0..3).map(|s| t.columns_of(s).len()).sum();
        assert_eq!(all, 10);
    }

    #[test]
    fn redundancy_gives_distinct_owners() {
        let t = Topology::new(12, &params(Some(4), 3));
        for j in 0..12 {
            let o = t.owners(j);
            assert_eq!(o.len(), 3);
            let mut u = o.to_vec();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), 3, "owners must be distinct");
        }
    }

    #[test]
    fn redundancy_clamped_to_splitters() {
        let t = Topology::new(5, &params(Some(2), 10));
        assert_eq!(t.redundancy(), 2);
    }

    #[test]
    fn default_one_splitter_per_column() {
        let t = Topology::new(7, &params(None, 1));
        assert_eq!(t.num_splitters(), 7);
        for j in 0..7 {
            assert_eq!(t.owners(j), &[j]);
        }
    }

    #[test]
    fn level_assignment_covers_candidates_once() {
        let t = Topology::new(20, &params(Some(5), 2));
        let cands = vec![1, 3, 3, 7, 12, 19];
        let a = t.assign_level(&cands);
        let mut assigned: Vec<usize> = a
            .per_splitter
            .values()
            .flat_map(|v| v.iter().copied())
            .collect();
        assigned.sort_unstable();
        assert_eq!(assigned, vec![1, 3, 7, 12, 19], "each candidate once");
        // Every column assigned to one of its owners.
        for (&s, cols) in &a.per_splitter {
            for &j in cols {
                assert!(t.owners(j).contains(&s));
            }
        }
        assert!(a.max_load >= 1);
        assert_eq!(a.owner_of(7), a.owner_of(7));
        assert_eq!(a.owner_of(2), None);
    }

    #[test]
    fn redundancy_reduces_max_load() {
        // With w splitters and w columns all candidates, d=1 can be
        // unlucky only if ownership collides — here ownership is
        // round-robin so load is 1. Make collisions: w=4, 16 columns,
        // candidates all in one shard mod 4.
        let t1 = Topology::new(16, &params(Some(4), 1));
        let cands: Vec<usize> = vec![0, 4, 8, 12]; // all owned by splitter 0
        let a1 = t1.assign_level(&cands);
        assert_eq!(a1.max_load, 4);
        let t2 = Topology::new(16, &params(Some(4), 2));
        let a2 = t2.assign_level(&cands);
        assert!(
            a2.max_load <= 2,
            "two choices should halve the load, got {}",
            a2.max_load
        );
    }
}
