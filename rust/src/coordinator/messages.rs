//! Protocol messages between tree builders and splitters (paper Alg. 2).
//!
//! Every message knows its wire size; the transport charges those bytes
//! to the network counters, which is how the benches reproduce Table 1's
//! network column. The sizes model a compact binary encoding (not the
//! in-memory layout): e.g. a condition-evaluation bitmap costs exactly
//! one bit per sample in the evaluated leaf — the paper's headline
//! "`Dn` bits in `D` allreduce".

use crate::splits::SplitCandidate;
use crate::tree::Condition;

/// A dense bitmap, one bit per sample of a leaf (in increasing sample
/// order). `true` routes the sample to the left child.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Bitmap {
    len: usize,
    words: Vec<u64>,
}

impl Bitmap {
    pub fn with_len(len: usize) -> Self {
        Self {
            len,
            words: vec![0u64; len.div_ceil(64)],
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        if v {
            self.words[i / 64] |= 1u64 << (i % 64);
        } else {
            self.words[i / 64] &= !(1u64 << (i % 64));
        }
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Assemble a bitmap from pre-filled packed words (the branchless
    /// condition-evaluation pass builds its bitmaps word-level and
    /// wraps them here). Bits at `len` and beyond must be zero; word
    /// count must match exactly.
    pub fn from_words(len: usize, words: Vec<u64>) -> Self {
        debug_assert_eq!(words.len(), len.div_ceil(64));
        debug_assert!(
            len % 64 == 0 || words.last().map_or(true, |w| w >> (len % 64) == 0),
            "stray bits beyond len"
        );
        Self { len, words }
    }

    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Wire size: ⌈len/8⌉ bytes — one bit per sample, as the paper counts.
    pub fn wire_bytes(&self) -> u64 {
        (self.len as u64).div_ceil(8)
    }
}

/// Per-open-leaf info shipped with a supersplit query, in leaf-rank
/// order (rank 1 = first entry).
#[derive(Debug, Clone, PartialEq)]
pub struct LeafInfo {
    /// Tree-structure node id (keys deterministic feature sampling).
    pub node_id: u32,
    /// Bagged label histogram of the leaf (the splitters need parent
    /// totals to score splits in one pass).
    pub totals: Vec<u64>,
    /// The leaf detaches into a resident subtree this level: it stays
    /// in the query positionally (ranks must stay aligned across the
    /// fleet) but splitters give it no candidates — its split is
    /// computed builder-side from the materialized rows, and the level
    /// update closes its rank with [`LeafOutcome::Detached`].
    pub detached: bool,
}

impl LeafInfo {
    pub fn wire_bytes(&self) -> u64 {
        4 + 1 + self.totals.len() as u64 * 8
    }
}

/// Tree builder → splitter: "find your partial optimal supersplit for
/// this depth level" (Alg. 2 step 3).
#[derive(Debug, Clone, PartialEq)]
pub struct SupersplitQuery {
    pub tree: u32,
    pub depth: u32,
    /// Open leaves, rank order.
    pub leaves: Vec<LeafInfo>,
    /// Columns this splitter should scan this level (the level's
    /// balanced column→replica assignment, see `topology`).
    pub assigned_columns: Vec<usize>,
}

impl SupersplitQuery {
    pub fn wire_bytes(&self) -> u64 {
        4 + 4
            + self.leaves.iter().map(|l| l.wire_bytes()).sum::<u64>()
            + self.assigned_columns.len() as u64 * 4
    }
}

/// Splitter → tree builder: best split found per leaf among the
/// splitter's assigned columns (`None` = no valid split found locally).
#[derive(Debug, Clone, PartialEq)]
pub struct PartialSupersplit {
    /// Indexed by leaf rank − 1.
    pub splits: Vec<Option<SplitCandidate>>,
}

impl PartialSupersplit {
    pub fn wire_bytes(&self) -> u64 {
        self.splits
            .iter()
            .map(|s| match s {
                None => 1,
                Some(c) => 1 + 8 + c.condition.wire_bytes() + c.left_counts.len() as u64 * 16,
            })
            .sum()
    }
}

/// Tree builder → the owning splitter: "evaluate the winning conditions
/// you own" (Alg. 2 step 5).
#[derive(Debug, Clone, PartialEq)]
pub struct EvalQuery {
    pub tree: u32,
    pub depth: u32,
    /// (leaf rank, condition) pairs, only for conditions whose feature
    /// this splitter owns.
    pub conditions: Vec<(u32, Condition)>,
}

impl EvalQuery {
    pub fn wire_bytes(&self) -> u64 {
        8 + self
            .conditions
            .iter()
            .map(|(_, c)| 4 + c.wire_bytes())
            .sum::<u64>()
    }
}

/// Splitter → tree builder: one bitmap per evaluated condition — "one
/// bit of information for each sample selected at least once in the
/// bagging and still in an open leaf" (Alg. 2 step 5).
#[derive(Debug, Clone, PartialEq)]
pub struct EvalResult {
    /// (leaf rank, bitmap over the leaf's samples in sample order).
    pub bitmaps: Vec<(u32, Bitmap)>,
}

impl EvalResult {
    pub fn wire_bytes(&self) -> u64 {
        self.bitmaps
            .iter()
            .map(|(_, b)| 4 + b.wire_bytes())
            .sum()
    }
}

/// What happened to each open leaf at the end of a depth level.
#[derive(Debug, Clone, PartialEq)]
pub enum LeafOutcome {
    /// The leaf closed (too few records, no positive-gain condition, or
    /// the depth limit was hit).
    Closed,
    /// The leaf split. `left_open` / `right_open` tell every worker
    /// whether each child remains active (and therefore receives a new
    /// rank) or is immediately closed (code 0). New ranks are assigned
    /// to open children in outcome order, left before right.
    Split {
        bitmap: Bitmap,
        left_open: bool,
        right_open: bool,
    },
    /// The leaf detached into a builder-resident subtree (depth-next
    /// growth): its rows were materialized, the builder grows the
    /// subtree locally, and the splitters stop tracking it — for the
    /// distributed class list this is exactly a close (code 0).
    Detached,
}

/// Tree builder → all splitters (broadcast): the level's outcomes so
/// every worker updates its class list identically (Alg. 2 steps 6-7).
#[derive(Debug, Clone, PartialEq)]
pub struct LevelUpdate {
    pub tree: u32,
    pub depth: u32,
    /// Indexed by old leaf rank − 1.
    pub outcomes: Vec<LeafOutcome>,
}

impl LevelUpdate {
    pub fn wire_bytes(&self) -> u64 {
        8 + self
            .outcomes
            .iter()
            .map(|o| match o {
                LeafOutcome::Closed | LeafOutcome::Detached => 1,
                LeafOutcome::Split { bitmap, .. } => 1 + bitmap.wire_bytes(),
            })
            .sum::<u64>()
    }

    /// Number of open leaves after applying this update.
    pub fn new_num_open(&self) -> u32 {
        self.outcomes
            .iter()
            .map(|o| match o {
                LeafOutcome::Closed | LeafOutcome::Detached => 0,
                LeafOutcome::Split {
                    left_open,
                    right_open,
                    ..
                } => *left_open as u32 + *right_open as u32,
            })
            .sum()
    }
}

/// Tree builder → splitter: "ship me the raw values of your assigned
/// columns for the in-bag rows of these detaching leaves" — the one
/// extra pass that buys depth-next growth all its later passes back.
/// Rows come back in ascending absolute-row order per leaf, in-bag rows
/// only, so every splitter's slices align positionally.
#[derive(Debug, Clone, PartialEq)]
pub struct MaterializeQuery {
    pub tree: u32,
    pub depth: u32,
    /// Class-list ranks (1-based, this level's numbering) of the
    /// detaching leaves.
    pub ranks: Vec<u32>,
    /// Columns this splitter should extract (a disjoint slice of the
    /// full feature set — the level assignment over all columns).
    pub columns: Vec<usize>,
    /// Also ship labels and bag weights for the leaves' rows (asked of
    /// exactly one splitter; every splitter holds the replicated label
    /// column).
    pub want_meta: bool,
}

impl MaterializeQuery {
    pub fn wire_bytes(&self) -> u64 {
        4 + 4 + 1 + self.ranks.len() as u64 * 4 + self.columns.len() as u64 * 4
    }
}

/// One detaching leaf's materialized rows (splitter → tree builder).
#[derive(Debug, Clone, PartialEq)]
pub struct MaterializedLeaf {
    /// In-bag row count (sanity-checked against the leaf's histogram).
    pub rows: u64,
    /// Labels per row (empty unless `want_meta`).
    pub labels: Vec<u32>,
    /// Bag weights per row (empty unless `want_meta`).
    pub bags: Vec<u8>,
    /// One entry per requested column, in query column order.
    pub columns: Vec<MaterializedColumn>,
}

/// One column's values for one materialized leaf.
#[derive(Debug, Clone, PartialEq)]
pub enum MaterializedColumn {
    /// Numerical values, row order.
    Num(Vec<f32>),
    /// Categorical codes, row order, with the column's arity.
    Cat { arity: u32, values: Vec<u32> },
}

impl MaterializedColumn {
    pub fn wire_bytes(&self) -> u64 {
        match self {
            MaterializedColumn::Num(v) => 1 + v.len() as u64 * 4,
            MaterializedColumn::Cat { values, .. } => 1 + 4 + values.len() as u64 * 4,
        }
    }
}

/// Splitter → tree builder: the materialized rows, one entry per
/// requested rank in query rank order.
#[derive(Debug, Clone, PartialEq)]
pub struct MaterializedLeaves {
    pub leaves: Vec<MaterializedLeaf>,
}

impl MaterializedLeaves {
    pub fn wire_bytes(&self) -> u64 {
        self.leaves
            .iter()
            .map(|l| {
                8 + l.labels.len() as u64 * 4
                    + l.bags.len() as u64
                    + l.columns.iter().map(|c| c.wire_bytes()).sum::<u64>()
            })
            .sum()
    }
}

/// Tree builder → all splitters (broadcast): a detached subtree
/// finished growing. Splitters hold no state for detached leaves, so
/// this is observational — workers validate the tree exists and bump
/// their local counters; the forest bytes themselves stay builder-side
/// (the paper's builders own structure, splitters own data).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubtreeDone {
    pub tree: u32,
    /// Node id of the subtree root (the detached leaf).
    pub root: u32,
    /// In-bag rows the subtree was grown over.
    pub rows: u64,
    /// Nodes the depth-first growth added (root excluded).
    pub nodes: u32,
}

impl SubtreeDone {
    pub fn wire_bytes(&self) -> u64 {
        4 + 4 + 8 + 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitmap_set_get_count() {
        let mut b = Bitmap::with_len(130);
        b.set(0, true);
        b.set(64, true);
        b.set(129, true);
        b.set(64, false);
        assert!(b.get(0) && !b.get(64) && b.get(129));
        assert_eq!(b.count_ones(), 2);
        assert_eq!(b.wire_bytes(), 17);
    }

    #[test]
    fn level_update_open_count() {
        let u = LevelUpdate {
            tree: 0,
            depth: 1,
            outcomes: vec![
                LeafOutcome::Closed,
                LeafOutcome::Split {
                    bitmap: Bitmap::with_len(4),
                    left_open: true,
                    right_open: false,
                },
                LeafOutcome::Split {
                    bitmap: Bitmap::with_len(4),
                    left_open: true,
                    right_open: true,
                },
            ],
        };
        assert_eq!(u.new_num_open(), 3);
    }

    #[test]
    fn wire_sizes_are_sane() {
        let q = SupersplitQuery {
            tree: 0,
            depth: 0,
            leaves: vec![LeafInfo {
                node_id: 0,
                totals: vec![10, 20],
                detached: false,
            }],
            assigned_columns: vec![0, 3],
        };
        assert_eq!(q.wire_bytes(), 4 + 4 + (4 + 1 + 16) + 8);
        let e = EvalResult {
            bitmaps: vec![(1, Bitmap::with_len(100))],
        };
        assert_eq!(e.wire_bytes(), 4 + 13);
        let m = MaterializeQuery {
            tree: 0,
            depth: 2,
            ranks: vec![1, 3],
            columns: vec![0, 5],
            want_meta: true,
        };
        assert_eq!(m.wire_bytes(), 4 + 4 + 1 + 8 + 8);
        let r = MaterializedLeaves {
            leaves: vec![MaterializedLeaf {
                rows: 3,
                labels: vec![0, 1, 0],
                bags: vec![1, 2, 1],
                columns: vec![
                    MaterializedColumn::Num(vec![0.5, 1.5, 2.5]),
                    MaterializedColumn::Cat {
                        arity: 4,
                        values: vec![0, 3, 1],
                    },
                ],
            }],
        };
        assert_eq!(r.wire_bytes(), 8 + 12 + 3 + (1 + 12) + (1 + 4 + 12));
        let d = SubtreeDone {
            tree: 1,
            root: 7,
            rows: 100,
            nodes: 12,
        };
        assert_eq!(d.wire_bytes(), 20);
    }
}
