//! The manager (paper §2): owns the splitter fleet and the tree
//! builders, runs the forest-level training loop, and assembles the
//! finished trees. Also home of the threaded worker engine.

use super::splitter::{
    disk_storage_for, disk_v2_storage_for, memory_storage_for, mmap_storage_for,
    remote_storage_for, SplitterConfig, SplitterCore,
};
use super::topology::Topology;
use super::transport::{DirectPool, SplitterPool};
use super::tree_builder::{LevelStats, TreeBuilderCore};
use crate::config::{Engine, ScorerBackend, StorageMode, TrainConfig};
use crate::data::io_stats::{IoSnapshot, IoStats};
use crate::data::Dataset;
use crate::metrics::Stopwatch;
use crate::rng::Bagger;
use crate::splits::xla_scorer::{ScoreTasks, ScorerService};
use crate::tree::Tree;
use crate::Result;
use std::sync::Arc;

/// Default XLA scorer block shape (must match an artifact produced by
/// `make artifacts`; see python/compile/aot.py).
pub const XLA_SCORER_BATCH: usize = 16;
pub const XLA_SCORER_THRESHOLDS: usize = 512;

/// Per-tree training report.
#[derive(Debug, Clone)]
pub struct TreeReport {
    pub tree: u32,
    pub seconds: f64,
    pub levels: Vec<LevelStats>,
}

/// Whole-run training report (feeds Table 2 / Figure 2 / Figure 3).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub per_tree: Vec<TreeReport>,
    pub wall_seconds: f64,
    /// Network traffic over the whole run.
    pub net: IoSnapshot,
    /// Per-splitter disk I/O.
    pub splitter_io: Vec<IoSnapshot>,
    /// Sum of class-list bits across splitters at peak (sampled after
    /// tree starts; approximate).
    pub num_splitters: usize,
}

impl TrainReport {
    /// Total training seconds across trees (the paper's "total training
    /// time of a tree is the sum of the training times of each depth
    /// level").
    pub fn total_tree_seconds(&self) -> f64 {
        self.per_tree.iter().map(|t| t.seconds).sum()
    }
}

/// The manager: builds the topology, spawns workers, trains the forest.
pub struct Manager {
    cfg: TrainConfig,
}

impl Manager {
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        cfg.validate()?;
        Ok(Self { cfg })
    }

    /// Train a forest on `ds`. Returns the trees (index = tree id) and
    /// the training report.
    pub fn train(&self, ds: &Dataset) -> Result<(Vec<Tree>, TrainReport)> {
        if self.cfg.engine == Engine::Cluster {
            return self.train_cluster(ds);
        }
        let sw = Stopwatch::start();
        let cfg = &self.cfg;
        let topology = Topology::new(ds.num_features(), &cfg.topology);

        // Dataset preparation (§2.1): shard columns to splitters,
        // presort numerical columns. Disk mode spills shards to files.
        let labels = Arc::new(ds.labels().to_vec());
        let splitter_cfg = SplitterConfig {
            seed: cfg.forest.seed,
            bagger: Bagger::new(cfg.forest.seed, cfg.forest.bagging),
            feature_sampling: cfg.forest.feature_sampling,
            num_candidates: cfg.forest.candidates_for(ds.num_features()),
            score_kind: cfg.forest.score_kind,
            prune: cfg.prune,
            scan_threads: cfg.scan_threads,
            split_search: cfg.split_search,
        };
        let tmp_dir = match cfg.storage {
            StorageMode::Disk | StorageMode::DiskV2 | StorageMode::Mmap => {
                Some(crate::util::tempdir()?)
            }
            // Remote without an external objstore: the manager spills
            // the dataset and self-hosts a loopback objstore over it.
            StorageMode::Remote if cfg.object_store.is_none() => Some(crate::util::tempdir()?),
            StorageMode::Memory | StorageMode::Remote => None,
        };

        // Remote storage: resolve the objstore address the splitters
        // will fetch from. `--object-store HOST:PORT` points at an
        // external `drf objstore` serving a dataset directory; with no
        // address the manager writes chunked DRFC v2 files into the
        // run's temp dir and serves them itself over real TCP (the
        // self-contained mode the storage matrix tests and benches
        // exercise). The server guard lives until training ends.
        let mut _objstore_guard: Option<crate::data::objserve::ObjStoreServer> = None;
        let objstore_addr: Option<String> = if cfg.storage == StorageMode::Remote {
            Some(match &cfg.object_store {
                Some(addr) => addr.clone(),
                None => {
                    let dir = tmp_dir
                        .as_ref()
                        .expect("loopback remote spills to the temp dir")
                        .path()
                        .join("objstore");
                    crate::data::store::save_dataset_with(
                        ds,
                        &dir,
                        crate::data::disk::Layout::V2 {
                            chunk_rows: crate::data::disk::DEFAULT_CHUNK_ROWS as u32,
                        },
                        IoStats::new(),
                    )?;
                    let server = crate::data::objserve::ObjStoreServer::spawn(
                        &dir,
                        "127.0.0.1:0",
                        IoStats::new(),
                        Default::default(),
                    )?;
                    let addr = server.addr().to_string();
                    _objstore_guard = Some(server);
                    addr
                }
            })
        } else {
            None
        };

        // Optional XLA scorer service (one per run; splitters share the
        // channel client).
        let scorer_service = match cfg.scorer {
            ScorerBackend::Native => None,
            ScorerBackend::Xla => {
                let dir = cfg
                    .artifacts_dir
                    .clone()
                    .unwrap_or_else(|| std::path::PathBuf::from("artifacts"));
                Some(ScorerService::spawn(
                    &dir,
                    XLA_SCORER_BATCH,
                    XLA_SCORER_THRESHOLDS,
                )?)
            }
        };

        let mut splitter_stats = Vec::new();
        let mut splitters = Vec::new();
        for s in 0..topology.num_splitters() {
            let cols = topology.columns_of(s);
            let stats = IoStats::new();
            splitter_stats.push(stats.clone());
            let storage = match cfg.storage {
                StorageMode::Memory => memory_storage_for(ds, &cols),
                StorageMode::Remote => remote_storage_for(
                    objstore_addr.as_deref().expect("resolved above"),
                    ds.schema(),
                    &cols,
                    stats.clone(),
                    cfg.prefetch_chunks,
                )?,
                mode => {
                    let dir = tmp_dir.as_ref().expect("disk modes spill to the temp dir");
                    let sub = dir.path().join(format!("splitter_{s}"));
                    std::fs::create_dir_all(&sub)?;
                    match mode {
                        StorageMode::DiskV2 => disk_v2_storage_for(
                            ds,
                            &cols,
                            &sub,
                            crate::data::disk::DEFAULT_CHUNK_ROWS as u32,
                            stats.clone(),
                            cfg.prefetch_chunks,
                        )?,
                        StorageMode::Mmap => mmap_storage_for(
                            ds,
                            &cols,
                            &sub,
                            crate::data::disk::DEFAULT_CHUNK_ROWS as u32,
                            stats.clone(),
                        )?,
                        _ => disk_storage_for(
                            ds,
                            &cols,
                            &sub,
                            stats.clone(),
                            cfg.prefetch_chunks,
                        )?,
                    }
                }
            };
            let mut core = SplitterCore::new(
                s,
                ds.schema().clone(),
                storage,
                labels.clone(),
                splitter_cfg,
                stats,
            );
            if let Some(service) = &scorer_service {
                let client: Arc<dyn ScoreTasks + Send + Sync> = Arc::new(service.client());
                core = core.with_xla(client);
            }
            splitters.push(Arc::new(core));
        }

        let trees_and_stats;
        let pool_net;
        match cfg.engine {
            Engine::Direct => {
                let pool = DirectPool::new(splitters, cfg.topology.latency_us);
                trees_and_stats = self.train_sequential(&pool, &topology, ds)?;
                pool_net = pool.net_stats();
            }
            Engine::Threaded => {
                let pool = DirectPool::new(splitters, cfg.topology.latency_us);
                trees_and_stats = self.train_threaded(&pool, &topology, ds)?;
                pool_net = pool.net_stats();
            }
            Engine::Tcp => {
                // Fully literal distribution: one TCP server per splitter,
                // binary codec on the wire (coordinator::tcp).
                let servers: Vec<crate::coordinator::tcp::SplitterServer> = splitters
                    .into_iter()
                    .map(crate::coordinator::tcp::SplitterServer::spawn)
                    .collect::<Result<_>>()?;
                let addrs: Vec<_> = servers.iter().map(|s| s.addr()).collect();
                let columns: Vec<_> = (0..topology.num_splitters())
                    .map(|s| topology.columns_of(s))
                    .collect();
                let pool = crate::coordinator::tcp::TcpPool::connect(&addrs, columns)?;
                trees_and_stats = self.train_sequential(&pool, &topology, ds)?;
                pool_net = pool.net_stats();
            }
            Engine::Cluster => unreachable!("handled above"),
        }

        Ok(assemble_report(
            trees_and_stats,
            sw.seconds(),
            pool_net.snapshot(),
            splitter_stats.iter().map(|s| s.snapshot()).collect(),
            topology.num_splitters(),
        ))
    }

    /// Train over a remote worker fleet (`Engine::Cluster`): the leader
    /// spawns no splitters and loads no columns — it connects a
    /// [`crate::cluster::ClusterPool`] to the addresses in the cluster
    /// manifest (or `cluster_workers`), validates the fleet via the
    /// Hello handshake, and wraps it in the replay-recovery layer so a
    /// worker killed and restarted mid-training rejoins transparently.
    /// `ds` anchors the leader-side expectations (feature/row/class
    /// counts) and downstream evaluation; its columns are never read.
    fn train_cluster(&self, ds: &Dataset) -> Result<(Vec<Tree>, TrainReport)> {
        let sw = Stopwatch::start();
        let cfg = &self.cfg;
        let path = cfg
            .cluster_manifest
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("--engine cluster needs --manifest cluster.json"))?;
        let manifest = crate::cluster::ClusterManifest::load(path)?;
        anyhow::ensure!(
            manifest.num_features == ds.num_features(),
            "dataset has {} features, cluster manifest declares {}",
            ds.num_features(),
            manifest.num_features
        );
        anyhow::ensure!(
            manifest.rows == ds.num_rows(),
            "dataset has {} rows, cluster manifest declares {}",
            ds.num_rows(),
            manifest.rows
        );
        anyhow::ensure!(
            manifest.num_classes == ds.num_classes(),
            "dataset has {} classes, cluster manifest declares {}",
            ds.num_classes(),
            manifest.num_classes
        );
        let topology = manifest.topology()?;
        let workers = if cfg.cluster_workers.is_empty() {
            manifest.workers.clone()
        } else {
            cfg.cluster_workers.clone()
        };
        anyhow::ensure!(
            !workers.is_empty(),
            "no worker addresses: record them in the cluster manifest or pass --workers"
        );
        let pool = crate::cluster::ClusterPool::connect(
            &workers,
            &topology,
            crate::cluster::hello_template(cfg, &manifest),
            manifest.rows as u64,
            manifest.num_classes,
            crate::cluster::ClusterOptions::default(),
        )?;
        // Watch the manifest so a supervisor's rewrites (rescheduled
        // addresses, an elastic drain) are adopted between trees.
        pool.watch_manifest(path.clone());
        let pool = crate::coordinator::recovery::RecoveringPool::new(pool);

        // Unlike the in-process engines, trees are built one at a time
        // against a per-tree topology snapshot: the ownership map may
        // change at tree boundaries (elastic re-shard), and per-level
        // column assignment only routes scans — every snapshot trains
        // the same forest (asserted by the drain drill in
        // tests/cluster.rs).
        let mut trees_and_stats = Vec::with_capacity(self.cfg.forest.num_trees);
        for t in 0..self.cfg.forest.num_trees as u32 {
            pool.inner().poll_topology()?;
            let topology = pool.inner().topology();
            let builder =
                TreeBuilderCore::new(&pool, &topology, &self.cfg.forest, ds.num_features())
                    .with_depth_next(self.cfg.depth_next_rows);
            let tree_sw = Stopwatch::start();
            let (tree, levels) = builder.build_tree(t)?;
            trees_and_stats.push((tree, levels, tree_sw.seconds()));
        }
        let num_splitters = pool.inner().topology().num_splitters();
        Ok(assemble_report(
            trees_and_stats,
            sw.seconds(),
            pool.net_stats().snapshot(),
            // Workers' disk I/O is accounted in their own processes.
            Vec::new(),
            num_splitters,
        ))
    }

    fn train_sequential(
        &self,
        pool: &dyn SplitterPool,
        topology: &Topology,
        ds: &Dataset,
    ) -> Result<Vec<(Tree, Vec<LevelStats>, f64)>> {
        let builder = TreeBuilderCore::new(pool, topology, &self.cfg.forest, ds.num_features())
            .with_depth_next(self.cfg.depth_next_rows);
        (0..self.cfg.forest.num_trees as u32)
            .map(|t| {
                let sw = Stopwatch::start();
                let (tree, levels) = builder.build_tree(t)?;
                Ok((tree, levels, sw.seconds()))
            })
            .collect()
    }

    /// Parallel tree building: `tree_builders` worker threads pull tree
    /// indices from a shared counter ("DRF trains all the trees in
    /// parallel", §2).
    fn train_threaded(
        &self,
        pool: &DirectPool,
        topology: &Topology,
        ds: &Dataset,
    ) -> Result<Vec<(Tree, Vec<LevelStats>, f64)>> {
        let num_trees = self.cfg.forest.num_trees;
        let num_builders = self.cfg.topology.tree_builders.min(num_trees.max(1));
        let next = std::sync::atomic::AtomicUsize::new(0);
        let results: Vec<std::sync::Mutex<Option<(Tree, Vec<LevelStats>, f64)>>> =
            (0..num_trees).map(|_| std::sync::Mutex::new(None)).collect();
        let params = &self.cfg.forest;
        let num_features = ds.num_features();
        let depth_next_rows = self.cfg.depth_next_rows;

        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::new();
            for _ in 0..num_builders {
                let next = &next;
                let results = &results;
                handles.push(scope.spawn(move || -> Result<()> {
                    let builder = TreeBuilderCore::new(pool, topology, params, num_features)
                        .with_depth_next(depth_next_rows);
                    loop {
                        let t = next.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                        if t >= num_trees {
                            return Ok(());
                        }
                        let sw = Stopwatch::start();
                        let (tree, levels) = builder.build_tree(t as u32)?;
                        *results[t].lock().unwrap() = Some((tree, levels, sw.seconds()));
                    }
                }));
            }
            for h in handles {
                h.join().map_err(|_| anyhow::anyhow!("tree builder panicked"))??;
            }
            Ok(())
        })?;

        results
            .into_iter()
            .enumerate()
            .map(|(t, m)| {
                m.into_inner()
                    .unwrap()
                    .ok_or_else(|| anyhow::anyhow!("tree {t} was not built"))
            })
            .collect()
    }
}

/// Assemble the per-tree reports and the run-level report.
fn assemble_report(
    trees_and_stats: Vec<(Tree, Vec<LevelStats>, f64)>,
    wall_seconds: f64,
    net: IoSnapshot,
    splitter_io: Vec<IoSnapshot>,
    num_splitters: usize,
) -> (Vec<Tree>, TrainReport) {
    let mut trees = Vec::with_capacity(trees_and_stats.len());
    let mut per_tree = Vec::with_capacity(trees_and_stats.len());
    for (t, (tree, levels, secs)) in trees_and_stats.into_iter().enumerate() {
        per_tree.push(TreeReport {
            tree: t as u32,
            seconds: secs,
            levels,
        });
        trees.push(tree);
    }
    let report = TrainReport {
        per_tree,
        wall_seconds,
        net,
        splitter_io,
        num_splitters,
    };
    (trees, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{Family, SyntheticSpec};
    use crate::rng::BaggingMode;

    fn small_cfg(trees: usize) -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.forest.num_trees = trees;
        cfg.forest.max_depth = 4;
        cfg.forest.seed = 11;
        cfg
    }

    #[test]
    fn trains_a_forest_end_to_end() {
        let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 500, 6, 3).generate();
        let m = Manager::new(small_cfg(3)).unwrap();
        let (trees, report) = m.train(&ds).unwrap();
        assert_eq!(trees.len(), 3);
        assert_eq!(report.per_tree.len(), 3);
        assert!(report.net.net_bytes > 0);
        assert!(report.wall_seconds > 0.0);
        assert_eq!(report.num_splitters, 6);
        // Bagged trees differ.
        assert_ne!(trees[0], trees[1]);
    }

    #[test]
    fn threaded_engine_matches_direct() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 300, 4, 3).generate();
        let mut cfg = small_cfg(2);
        cfg.forest.bagging = BaggingMode::Poisson;
        let (trees_direct, _) = Manager::new(cfg.clone()).unwrap().train(&ds).unwrap();
        cfg.engine = Engine::Threaded;
        cfg.topology.tree_builders = 2;
        let (trees_threaded, _) = Manager::new(cfg).unwrap().train(&ds).unwrap();
        assert_eq!(trees_direct, trees_threaded, "engine must not change the model");
    }

    #[test]
    fn disk_storage_matches_memory() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 200, 4, 3).generate();
        let cfg = small_cfg(1);
        let (mem_trees, _) = Manager::new(cfg.clone()).unwrap().train(&ds).unwrap();
        let mut cfg2 = cfg;
        cfg2.storage = StorageMode::Disk;
        let (disk_trees, report) = Manager::new(cfg2.clone()).unwrap().train(&ds).unwrap();
        assert_eq!(mem_trees, disk_trees, "storage mode must not change the model");
        // Disk mode must actually have read from disk.
        let total_read: u64 = report.splitter_io.iter().map(|s| s.disk_read_bytes).sum();
        assert!(total_read > 0);
        // The chunked v2 layout is bit-identical too.
        cfg2.storage = StorageMode::DiskV2;
        let (v2_trees, report) = Manager::new(cfg2.clone()).unwrap().train(&ds).unwrap();
        assert_eq!(mem_trees, v2_trees, "DRFC v2 must not change the model");
        let total_read: u64 = report.splitter_io.iter().map(|s| s.disk_read_bytes).sum();
        assert!(total_read > 0);
        // The zero-copy mmap backend is bit-identical too, and its
        // first-touch passes still register as disk reads.
        cfg2.storage = StorageMode::Mmap;
        let (mmap_trees, report) = Manager::new(cfg2.clone()).unwrap().train(&ds).unwrap();
        assert_eq!(mem_trees, mmap_trees, "mmap must not change the model");
        let total_read: u64 = report.splitter_io.iter().map(|s| s.disk_read_bytes).sum();
        assert!(total_read > 0);
        // And prefetching disk scans change nothing but wall clock.
        cfg2.storage = StorageMode::DiskV2;
        cfg2.prefetch_chunks = 2;
        let (pf_trees, _) = Manager::new(cfg2.clone()).unwrap().train(&ds).unwrap();
        assert_eq!(mem_trees, pf_trees, "prefetch must not change the model");
        // The remote object-store backend (self-hosted loopback
        // objstore, every scan a range read over a real socket) is
        // bit-identical too.
        cfg2.storage = StorageMode::Remote;
        cfg2.prefetch_chunks = 0;
        let (remote_trees, report) = Manager::new(cfg2).unwrap().train(&ds).unwrap();
        assert_eq!(mem_trees, remote_trees, "remote must not change the model");
        let total_read: u64 = report.splitter_io.iter().map(|s| s.disk_read_bytes).sum();
        assert!(total_read > 0);
        let total_net: u64 = report.splitter_io.iter().map(|s| s.net_bytes).sum();
        assert!(total_net > 0, "remote scans must cross the wire");
    }

    #[test]
    fn scan_threads_do_not_change_the_model() {
        let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 400, 6, 3).generate();
        let mut cfg = small_cfg(2);
        // 2 splitters x 3 columns each: the scan pool has real work.
        cfg.topology.num_splitters = Some(2);
        let (serial, _) = Manager::new(cfg.clone()).unwrap().train(&ds).unwrap();
        cfg.scan_threads = 4;
        let (parallel, _) = Manager::new(cfg).unwrap().train(&ds).unwrap();
        assert_eq!(serial, parallel, "scan_threads must not change the model");
    }
}
