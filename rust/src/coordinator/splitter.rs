//! The splitter worker (paper §2): owns a subset of columns, finds
//! partial optimal supersplits, evaluates winning conditions, and keeps
//! its own copy of every in-training tree's class list.
//!
//! A splitter never sees the tree structure and never talks to other
//! splitters — only to tree builders, via the message types in
//! [`super::messages`]. All dataset access goes through the
//! [`ColumnStore`] data plane as **chunk-granular sequential scans**:
//! in the disk backends every pass streams through a bounded buffer and
//! is charged to the worker's [`IoStats`] (this is what the Table 1
//! bench measures); the memory backend visits borrowed slices.
//!
//! A splitter owning `k` columns scans them **in parallel** on a scoped
//! worker pool bounded by [`SplitterConfig::scan_threads`]. Per-column
//! scan results are merged in deterministic column order, so the thread
//! count can never change a split decision — trees are bit-identical
//! for any `scan_threads` (asserted by `tests/storage_backends.rs`).

use super::messages::{
    Bitmap, EvalQuery, EvalResult, LevelUpdate, MaterializeQuery, MaterializedColumn,
    MaterializedLeaf, MaterializedLeaves, PartialSupersplit, SubtreeDone, SupersplitQuery,
};
use crate::classlist::ClassList;
use crate::config::{PruneMode, SplitSearch};
use crate::data::column::SortedEntry;
use crate::data::io_stats::IoStats;
use crate::data::schema::{ColumnType, Schema};
use crate::data::store::{self, ColumnStore, RawChunk};
use crate::rng::{Bagger, FeatureSampler, FeatureSampling};
use crate::splits::histogram::Histogram;
use crate::splits::scorer::{pick_best, ScoreKind};
use crate::splits::xla_scorer::{best_numerical_supersplit_xla, ScoreTasks};
use crate::splits::{categorical, numerical, SplitCandidate};
use crate::tree::{CategorySet, Condition};
use crate::Result;
use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

/// Static configuration every splitter shares (derived from the forest
/// params; identical across workers — that is what makes seeded bagging
/// and feature sampling consistent).
#[derive(Debug, Clone, Copy)]
pub struct SplitterConfig {
    pub seed: u64,
    pub bagger: Bagger,
    pub feature_sampling: FeatureSampling,
    pub num_candidates: usize,
    pub score_kind: ScoreKind,
    pub prune: PruneMode,
    /// Upper bound on concurrent column scans inside this splitter
    /// (1 = fully sequential). Never affects results, only wall clock.
    pub scan_threads: usize,
    /// Exhaustive scan (the default, exact) or MABSplit-style
    /// successive elimination before the exact final pass (opt-in,
    /// approximate).
    pub split_search: SplitSearch,
}

/// Per-tree state a splitter maintains.
struct TreeState {
    class_list: ClassList,
    /// Cached bag multiplicities (one byte per sample). Recomputable
    /// from the seed at any time (that is what recovery does); cached
    /// because the hash would otherwise be re-evaluated once per row
    /// per scanned column per level (EXPERIMENTS.md §Perf).
    bag_weights: Vec<u8>,
    /// SPRINT-style pruned attribute lists (adaptive mode only): sorted
    /// entries filtered to samples still in open leaves.
    pruned_sorted: Option<BTreeMap<usize, Vec<SortedEntry>>>,
    /// Presorted columns materialized for the XLA scoring path, cached
    /// per column for the current level round (cleared on every level
    /// update). Without it, every supersplit query on a disk backend
    /// re-materialized the full column per query; with it, a level
    /// charges exactly one chunked pass per column, like the native
    /// scan path.
    sorted_cache: Mutex<HashMap<usize, Arc<Vec<SortedEntry>>>>,
    /// Next depth level this tree's class list expects. Makes level
    /// updates idempotent: an at-least-once transport (the cluster
    /// pool re-issues a request after a reconnect) may deliver the
    /// same `LevelUpdate` twice, and applying the class-list
    /// transition twice would corrupt the mapping.
    next_depth: u32,
}

/// A materialized presorted view: borrowed straight from storage (or a
/// pruned per-tree list), or shared out of the per-level cache.
enum SortedView<'a> {
    Borrowed(&'a [SortedEntry]),
    Cached(Arc<Vec<SortedEntry>>),
}

impl SortedView<'_> {
    fn as_slice(&self) -> &[SortedEntry] {
        match self {
            SortedView::Borrowed(s) => s,
            SortedView::Cached(v) => v.as_slice(),
        }
    }
}

/// The splitter worker core (synchronous; thread wiring lives in
/// `manager`).
pub struct SplitterCore {
    id: usize,
    schema: Schema,
    /// The data plane: all column access is chunked sequential scans.
    storage: Arc<dyn ColumnStore>,
    /// Label column — replicated on every splitter at dataset-prep time.
    labels: Arc<Vec<u32>>,
    cfg: SplitterConfig,
    trees: Mutex<HashMap<u32, TreeState>>,
    stats: IoStats,
    /// Optional XLA scoring backend (numerical splits, binary labels).
    xla: Option<Arc<dyn ScoreTasks + Send + Sync>>,
}

impl SplitterCore {
    pub fn new(
        id: usize,
        schema: Schema,
        storage: Arc<dyn ColumnStore>,
        labels: Arc<Vec<u32>>,
        cfg: SplitterConfig,
        stats: IoStats,
    ) -> Self {
        Self {
            id,
            schema,
            storage,
            labels,
            cfg,
            trees: Mutex::new(HashMap::new()),
            stats,
            xla: None,
        }
    }

    /// Install the XLA scoring backend.
    pub fn with_xla(mut self, scorer: Arc<dyn ScoreTasks + Send + Sync>) -> Self {
        self.xla = Some(scorer);
        self
    }

    pub fn id(&self) -> usize {
        self.id
    }

    pub fn stats(&self) -> &IoStats {
        &self.stats
    }

    /// Columns this splitter holds.
    pub fn columns_owned(&self) -> Vec<usize> {
        self.storage.columns()
    }

    /// Rows in the (replicated) label column — the dataset row count.
    pub fn num_rows(&self) -> usize {
        self.labels.len()
    }

    pub fn num_classes(&self) -> u32 {
        self.schema.num_classes
    }

    fn sampler(&self) -> FeatureSampler {
        FeatureSampler::new(
            self.cfg.seed,
            self.schema.num_features(),
            self.cfg.num_candidates,
            self.cfg.feature_sampling,
        )
    }

    /// SPRINT-pruned per-tree entries of column `j`, if active, with
    /// the pass charged (a pruned scan still reads data — same
    /// accounting as the chunked store paths).
    fn charged_pruned_entries<'a>(
        &self,
        state: &'a TreeState,
        j: usize,
    ) -> Option<&'a [SortedEntry]> {
        let entries = state
            .pruned_sorted
            .as_ref()?
            .get(&j)
            .map(|v| v.as_slice())?;
        self.stats.add_disk_read(entries.len() as u64 * 8);
        self.stats.add_read_pass();
        Some(entries)
    }

    /// Whole presorted view of column `j` for consumers that need the
    /// full slice at once (the XLA scorer): the pruned per-tree copy
    /// when active, a zero-copy borrow when the backend holds the view
    /// resident (MemStore, MmapStore), else the per-level cache —
    /// filled by one materializing pass over the store, charged exactly
    /// like the chunked native-scan path, then reused free of charge
    /// for the rest of the level round (like a resident borrow).
    fn materialize_sorted<'a>(
        &'a self,
        state: &'a TreeState,
        j: usize,
    ) -> Result<SortedView<'a>> {
        if let Some(entries) = self.charged_pruned_entries(state, j) {
            return Ok(SortedView::Borrowed(entries));
        }
        if let Some(entries) = self.storage.borrow_sorted(j) {
            return Ok(SortedView::Borrowed(entries));
        }
        if let Some(cached) = state.sorted_cache.lock().unwrap().get(&j) {
            return Ok(SortedView::Cached(cached.clone()));
        }
        // Fill outside the lock: parallel scan jobs materialize
        // *different* columns and must not serialize on each other.
        let entries = Arc::new(self.storage.read_sorted(j)?);
        state
            .sorted_cache
            .lock()
            .unwrap()
            .insert(j, entries.clone());
        Ok(SortedView::Cached(entries))
    }

    // ------------------------------------------------------------------
    // RPC handlers
    // ------------------------------------------------------------------

    /// Begin training a tree: initialize its class list. In-bag samples
    /// go to the root (code 1); out-of-bag samples are code 0 (they are
    /// never counted and never shipped in bitmaps — paper Alg. 2 step 5).
    pub fn start_tree(&self, tree: u32) {
        let n = self.num_rows();
        let mut cl = ClassList::with_open(n, 1);
        let mut weights = vec![0u8; n];
        for (i, w) in weights.iter_mut().enumerate() {
            let b = self.cfg.bagger.weight(tree, i as u64).min(255) as u8;
            *w = b;
            if b > 0 {
                cl.set(i, 1);
            }
        }
        self.trees.lock().unwrap().insert(
            tree,
            TreeState {
                class_list: cl,
                bag_weights: weights,
                pruned_sorted: None,
                sorted_cache: Mutex::new(HashMap::new()),
                next_depth: 0,
            },
        );
    }

    /// Bagged label histogram of the root (queried once per tree by the
    /// tree builder, which owns no data). A chunk-granular fold like
    /// every other scan — the label column just happens to always live
    /// in RAM.
    pub fn root_stats(&self, tree: u32) -> Vec<u64> {
        let mut h = Histogram::new(self.num_classes());
        let mut base = 0u64;
        for chunk in self.labels.chunks(crate::data::disk::DEFAULT_CHUNK_ROWS) {
            for (k, &y) in chunk.iter().enumerate() {
                let b = self.cfg.bagger.weight(tree, base + k as u64);
                if b > 0 {
                    h.add(y, b);
                }
            }
            base += chunk.len() as u64;
        }
        h.into_counts()
    }

    /// Alg. 2 step 3: find this splitter's partial optimal supersplit.
    ///
    /// Columns are scanned independently (in parallel up to
    /// `scan_threads`); per-column candidates are merged with
    /// [`pick_best`] in assigned-column order, so the result is
    /// identical to a fully sequential pass.
    pub fn find_splits(&self, q: &SupersplitQuery) -> Result<PartialSupersplit> {
        let _span = crate::span!("find_splits", tree = q.tree, depth = q.depth);
        let trees = self.trees.lock().unwrap();
        let state = trees
            .get(&q.tree)
            .ok_or_else(|| anyhow::anyhow!("splitter {}: unknown tree {}", self.id, q.tree))?;
        let cl = &state.class_list;
        anyhow::ensure!(
            cl.num_open() as usize == q.leaves.len(),
            "class list has {} open leaves, query has {}",
            cl.num_open(),
            q.leaves.len()
        );

        let sampler = self.sampler();
        // Per-leaf candidate feature sets (computed locally from the
        // seed — zero communication, paper §2.2's trick applied to
        // features). Detached leaves draw no candidates: their subtree
        // now grows depth-first on the tree builder, so no splitter
        // proposes splits for them (they stay positionally in the
        // query until the level update closes them).
        let leaf_candidates: Vec<Vec<usize>> = q
            .leaves
            .iter()
            .map(|l| {
                if l.detached {
                    Vec::new()
                } else {
                    sampler.candidates(q.tree, q.depth, l.node_id)
                }
            })
            .collect();
        let leaf_totals: Vec<Histogram> = q
            .leaves
            .iter()
            .map(|l| Histogram::from_counts(l.totals.clone()))
            .collect();

        // Columns drawn for at least one leaf, with their per-leaf
        // candidacy masks; a non-candidate column skips its pass
        // entirely.
        let mut jobs: Vec<(usize, Vec<bool>)> = q
            .assigned_columns
            .iter()
            .filter_map(|&j| {
                let mask: Vec<bool> = leaf_candidates.iter().map(|c| c.contains(&j)).collect();
                mask.iter().any(|&b| b).then_some((j, mask))
            })
            .collect();

        // Opt-in MABSplit elimination: a strided sample pass thins the
        // (leaf, column) arms before the exact pass below.
        if self.cfg.split_search == SplitSearch::Mab && jobs.len() > 1 {
            jobs = self.mab_eliminate(q, state, jobs)?;
        }

        // Row throughput accounting: each job is one full-column pass.
        crate::telemetry::counter("drf_splitter_rows_scanned_total")
            .add(jobs.len() as u64 * self.num_rows() as u64);
        crate::telemetry::counter("drf_splitter_column_passes_total").add(jobs.len() as u64);

        let per_column = store::run_scans(self.cfg.scan_threads, jobs.len(), |k| {
            let (j, mask) = &jobs[k];
            self.scan_column_supersplit(*j, mask, state, &leaf_totals, 1)
        })?;

        let mut best: Vec<Option<SplitCandidate>> = vec![None; q.leaves.len()];
        for candidates in per_column {
            for (leaf, cand) in candidates.into_iter().enumerate() {
                if let Some(c) = cand {
                    best[leaf] = pick_best([best[leaf].take(), Some(c)].into_iter().flatten());
                }
            }
        }
        Ok(PartialSupersplit { splits: best })
    }

    /// MABSplit-style successive elimination (arXiv 2212.07473),
    /// deterministic and seedless: every candidate (leaf, column) arm
    /// is scored on a strided row sample, and arms whose sampled gain
    /// plus twice the confidence radius cannot reach their leaf's
    /// sampled leader are eliminated. The survivors get the exact final
    /// scan in `find_splits`, so the returned split is exact
    /// *conditional on the surviving set* — the elimination itself is
    /// explicitly approximate (`--split-search mab`; the ablation bench
    /// quantifies the AUC/time trade against the exact default).
    fn mab_eliminate(
        &self,
        q: &SupersplitQuery,
        state: &TreeState,
        jobs: Vec<(usize, Vec<bool>)>,
    ) -> Result<Vec<(usize, Vec<bool>)>> {
        // Stride from the live (non-detached) bagged population: aim
        // at ~4k sampled rows. Below ~8k rows the sample would be the
        // dataset itself — run exact directly.
        let live: u64 = q
            .leaves
            .iter()
            .filter(|l| !l.detached)
            .map(|l| l.totals.iter().sum::<u64>())
            .sum();
        if live < 8192 {
            return Ok(jobs);
        }
        let stride = ((live / 4096).next_power_of_two() as u32).min(1 << 16);

        // Sampled per-leaf class totals: the sampled scans must score
        // against the totals of the sampled population, not the full
        // leaf (the scan derives right-side counts from them).
        let cl = &state.class_list;
        let bag_weights = &state.bag_weights;
        let mut sampled_totals: Vec<Histogram> = q
            .leaves
            .iter()
            .map(|_| Histogram::new(self.num_classes()))
            .collect();
        for i in (0..self.num_rows()).step_by(stride as usize) {
            let h = cl.get(i);
            let b = bag_weights[i] as u32;
            if h > 0 && b > 0 {
                sampled_totals[(h - 1) as usize].add(self.labels[i], b);
            }
        }

        crate::telemetry::counter("drf_splitter_rows_scanned_total")
            .add(jobs.len() as u64 * (self.num_rows() as u64 / stride as u64));
        crate::telemetry::counter("drf_splitter_column_passes_total").add(jobs.len() as u64);
        crate::telemetry::counter("drf_mab_sampled_rounds_total").add(1);

        let sampled = store::run_scans(self.cfg.scan_threads, jobs.len(), |k| {
            let (j, mask) = &jobs[k];
            self.scan_column_supersplit(*j, mask, state, &sampled_totals, stride)
        })?;

        // Gains are impurity decreases, bounded by the score's range —
        // that bound drives the Hoeffding confidence radius.
        let range = match self.cfg.score_kind {
            ScoreKind::Gini => 1.0,
            ScoreKind::Entropy => (self.num_classes().max(2) as f64).log2(),
        };
        let mut keep: Vec<Vec<bool>> = jobs.iter().map(|(_, m)| m.clone()).collect();
        let mut pruned = 0u64;
        for r in 0..q.leaves.len() {
            let arms: Vec<usize> = (0..jobs.len()).filter(|&k| jobs[k].1[r]).collect();
            if arms.len() < 2 {
                continue;
            }
            let n_s = sampled_totals[r].total();
            if n_s == 0 {
                continue; // no sampled rows in this leaf — keep all arms
            }
            // A sampled arm with no valid split scores 0; if *every*
            // arm scores 0, the leader is 0 and all arms survive (the
            // degenerate-sample fallback).
            let gains: Vec<f64> = arms
                .iter()
                .map(|&k| sampled[k][r].as_ref().map_or(0.0, |c| c.gain))
                .collect();
            let leader = gains.iter().cloned().fold(0.0f64, f64::max);
            let eps =
                range * ((4.0 * arms.len() as f64).ln().max(1.0) / (2.0 * n_s as f64)).sqrt();
            for (ai, &k) in arms.iter().enumerate() {
                if gains[ai] + 2.0 * eps < leader {
                    keep[k][r] = false;
                    pruned += 1;
                }
            }
        }
        crate::telemetry::counter("drf_mab_arms_pruned_total").add(pruned);
        Ok(jobs
            .into_iter()
            .zip(keep)
            .filter_map(|((j, _), mask)| mask.iter().any(|&b| b).then_some((j, mask)))
            .collect())
    }

    /// One column's contribution to the supersplit: a chunk-granular
    /// scan through the store feeding the incremental Alg. 1 /
    /// count-table state.
    ///
    /// The per-sample class-list + bag-weight gather is table-driven:
    /// the per-leaf candidacy mask becomes a rank-indexed byte table,
    /// so "is this sample live for this column" folds to two loads and
    /// one multiply instead of the historical closed-leaf /
    /// non-candidate / out-of-bag branch ladder (BENCH_hotpath.json
    /// `supersplit gather`).
    fn scan_column_supersplit(
        &self,
        j: usize,
        mask: &[bool],
        state: &TreeState,
        leaf_totals: &[Histogram],
        stride: u32,
    ) -> Result<Vec<Option<SplitCandidate>>> {
        let cl = &state.class_list;
        let bag_weights = &state.bag_weights;
        // Rank → "feature drawn for this leaf" (index 0 = closed leaf,
        // never a candidate).
        let mut cand_tbl = vec![0u8; mask.len() + 1];
        for (r, &m) in mask.iter().enumerate() {
            cand_tbl[r + 1] = m as u8;
        }
        if stride > 1 {
            // Strided sample pass (MAB): only rows on the stride are
            // live. The XLA batch path has no notion of the stride, so
            // sampled passes always use the native scans.
            let smask = stride - 1;
            let gather = move |i: u32| {
                let h = cl.get(i as usize);
                let b = bag_weights[i as usize] as u32;
                let live = (cand_tbl[h as usize] as u32)
                    & (b != 0) as u32
                    & (i & smask == 0) as u32;
                (h * live, b)
            };
            return self.scan_column_gather(j, mask, state, leaf_totals, gather, false);
        }
        let gather = move |i: u32| {
            let h = cl.get(i as usize);
            let b = bag_weights[i as usize] as u32;
            let live = (cand_tbl[h as usize] as u32) & (b != 0) as u32;
            (h * live, b)
        };
        self.scan_column_gather(j, mask, state, leaf_totals, gather, true)
    }

    /// The scan body shared by the exact and the strided (MAB sampled)
    /// passes: everything downstream of the gather closure.
    fn scan_column_gather(
        &self,
        j: usize,
        mask: &[bool],
        state: &TreeState,
        leaf_totals: &[Histogram],
        gather: impl Fn(u32) -> (u32, u32),
        allow_xla: bool,
    ) -> Result<Vec<Option<SplitCandidate>>> {
        let cl = &state.class_list;
        let bag_weights = &state.bag_weights;
        match self.schema.columns[j].ctype {
            ColumnType::Numerical => {
                if let (Some(scorer), 2, true) = (&self.xla, self.num_classes(), allow_xla) {
                    // The batched XLA task builder needs the whole
                    // presorted slice at once.
                    let q_j = self.materialize_sorted(state, j)?;
                    return best_numerical_supersplit_xla(
                        scorer.as_ref(),
                        j,
                        q_j.as_slice(),
                        &self.labels,
                        leaf_totals,
                        |i| cl.get(i as usize),
                        |h| mask[(h - 1) as usize],
                        |i| bag_weights[i as usize] as u32,
                    );
                }
                let mut scan = numerical::NumericalSupersplitScan::new(
                    j,
                    &self.labels,
                    self.num_classes(),
                    leaf_totals,
                    self.cfg.score_kind,
                    gather,
                );
                if let Some(entries) = self.charged_pruned_entries(state, j) {
                    scan.push(entries);
                } else {
                    self.storage.scan_sorted(j, &mut |chunk| {
                        scan.push(chunk);
                        Ok(())
                    })?;
                }
                Ok(scan.finish())
            }
            ColumnType::Categorical { arity } => {
                let mut scan = categorical::CategoricalSupersplitScan::new(
                    j,
                    arity,
                    &self.labels,
                    self.num_classes(),
                    leaf_totals,
                    self.cfg.score_kind,
                    gather,
                );
                self.storage.scan_raw(j, &mut |base, chunk| match chunk {
                    RawChunk::Categorical(values) => {
                        scan.push(base, values);
                        Ok(())
                    }
                    RawChunk::Numerical(_) => {
                        anyhow::bail!("column {j}: chunk/type mismatch")
                    }
                })?;
                Ok(scan.finish())
            }
        }
    }

    /// Alg. 2 step 5: evaluate the winning conditions this splitter owns
    /// and return one dense bitmap per condition (one bit per in-bag
    /// sample of the leaf, in increasing sample order).
    ///
    /// Conditions are grouped by feature and each feature's column is
    /// scanned **once per level**, no matter how many leaves chose it —
    /// the per-level (not per-node) pass structure the paper's
    /// complexity analysis relies on (see EXPERIMENTS.md §Perf).
    /// Distinct features own disjoint condition slots, so the passes
    /// run in parallel up to `scan_threads`.
    pub fn eval_conditions(&self, q: &EvalQuery) -> Result<EvalResult> {
        let _span = crate::span!("eval_conditions", tree = q.tree, depth = q.depth);
        let trees = self.trees.lock().unwrap();
        let state = trees
            .get(&q.tree)
            .ok_or_else(|| anyhow::anyhow!("splitter {}: unknown tree {}", self.id, q.tree))?;
        let cl = &state.class_list;

        let max_rank = q.conditions.iter().map(|(r, _)| *r).max().unwrap_or(0) as usize;
        let counts = cl.histogram();
        for (rank, _) in &q.conditions {
            anyhow::ensure!((*rank as usize) < counts.len(), "rank {rank} out of range");
        }

        // Group condition slots by feature; one sequential pass each.
        let mut by_feature: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (slot, (_, cond)) in q.conditions.iter().enumerate() {
            by_feature.entry(cond.feature()).or_default().push(slot);
        }
        let groups: Vec<(usize, Vec<usize>)> = by_feature.into_iter().collect();
        crate::telemetry::counter("drf_splitter_eval_passes_total").add(groups.len() as u64);

        let results = store::run_scans(self.cfg.scan_threads, groups.len(), |g| {
            let (feature, slots) = &groups[g];
            self.eval_feature_pass(*feature, slots, &q.conditions, cl, &counts, max_rank)
        })?;

        // Reassemble in slot (condition) order.
        let mut out: Vec<Option<(u32, Bitmap)>> = q.conditions.iter().map(|_| None).collect();
        for group in results {
            for (slot, bm) in group {
                out[slot] = Some((q.conditions[slot].0, bm));
            }
        }
        let bitmaps = out
            .into_iter()
            .map(|o| o.expect("every condition slot belongs to exactly one feature pass"))
            .collect();
        Ok(EvalResult { bitmaps })
    }

    /// One feature's evaluation pass: a chunked scan over the raw
    /// column filling the bitmaps of this feature's condition slots.
    ///
    /// The per-row fill is branchless (BENCH_hotpath.json `eval bitmap
    /// fill`): condition payloads (threshold / category set) are
    /// hoisted out of the loop into per-slot tables — the historical
    /// loop re-matched the `Condition` enum **per row** — and every row
    /// is routed through a rank→slot table. Rows whose rank carries no
    /// condition here land on a trailing *trash slot* whose single word
    /// absorbs the writes (its word index is masked to 0), so the
    /// inner loop is a fixed load/compare/OR sequence with no
    /// data-dependent branches. Class-list codes are decoded
    /// chunk-wise with the word-level [`ClassList::decode_into`]
    /// instead of per-row bit extraction.
    fn eval_feature_pass(
        &self,
        feature: usize,
        slots: &[usize],
        conditions: &[(u32, Condition)],
        cl: &ClassList,
        counts: &[u64],
        max_rank: usize,
    ) -> Result<Vec<(usize, Bitmap)>> {
        // Rank → local slot; ranks are unique across conditions, so
        // each belongs to exactly one slot. Unclaimed ranks (and rank
        // 0 = closed) route to the trash slot.
        let trash = slots.len();
        let mut slot_of = vec![trash; counts.len().max(max_rank + 1)];

        let ctype = self.schema.columns[feature].ctype;
        // Per-slot payloads, one trailing trash entry each. The trash
        // threshold is NaN (`v <= NaN` is false) and the trash set is
        // empty, so trash bits are always 0 — not that anyone reads
        // them.
        let mut thresholds = vec![f32::NAN; slots.len() + 1];
        let trash_set = CategorySet::empty(match ctype {
            ColumnType::Categorical { arity } => arity,
            ColumnType::Numerical => 0,
        });
        let mut sets: Vec<&CategorySet> = vec![&trash_set; slots.len() + 1];
        // Bitmap words, flattened: slot li owns words[offset[li]..offset[li+1]].
        let mut lens = Vec::with_capacity(slots.len());
        let mut offset = Vec::with_capacity(slots.len() + 2);
        let mut nwords = 0usize;
        for (li, &slot) in slots.iter().enumerate() {
            let rank = conditions[slot].0 as usize;
            slot_of[rank] = li;
            // Validate the condition type once per slot, not per row.
            match (&conditions[slot].1, ctype) {
                (Condition::NumLe { threshold, .. }, ColumnType::Numerical) => {
                    thresholds[li] = *threshold;
                }
                (Condition::CatIn { set, .. }, ColumnType::Categorical { .. }) => {
                    sets[li] = set;
                }
                _ => anyhow::bail!("type mismatch on feature {feature}"),
            }
            let len = counts[rank] as usize;
            lens.push(len);
            offset.push(nwords);
            nwords += len.div_ceil(64);
        }
        offset.push(nwords); // trash words start here
        let mut words = vec![0u64; nwords + 1]; // +1 = the trash word
        // Word-index mask: identity for real slots, 0 for trash (all
        // trash writes land on its single word).
        let mut wmask = vec![usize::MAX; slots.len() + 1];
        wmask[trash] = 0;
        let mut cursor = vec![0usize; slots.len() + 1];
        let mut codes: Vec<u32> = Vec::new();

        self.storage.scan_raw(feature, &mut |base, chunk| {
            codes.resize(chunk.len(), 0);
            cl.decode_into(base, &mut codes);
            match chunk {
                RawChunk::Numerical(vals) => {
                    for (k, &v) in vals.iter().enumerate() {
                        let li = slot_of[codes[k] as usize];
                        let p = cursor[li];
                        let bit = (v <= thresholds[li]) as u64;
                        words[offset[li] + ((p >> 6) & wmask[li])] |= bit << (p & 63);
                        cursor[li] = p + 1;
                    }
                }
                RawChunk::Categorical(vals) => {
                    for (k, &v) in vals.iter().enumerate() {
                        let li = slot_of[codes[k] as usize];
                        let p = cursor[li];
                        let bit = sets[li].contains(v) as u64;
                        words[offset[li] + ((p >> 6) & wmask[li])] |= bit << (p & 63);
                        cursor[li] = p + 1;
                    }
                }
            }
            Ok(())
        })?;

        Ok(slots
            .iter()
            .enumerate()
            .map(|(li, &slot)| {
                debug_assert_eq!(cursor[li], lens[li], "slot fill must cover the leaf");
                let bm = Bitmap::from_words(lens[li], words[offset[li]..offset[li + 1]].to_vec());
                (slot, bm)
            })
            .collect())
    }

    /// Depth-next detach (paper complement, arXiv 1910.06853): extract
    /// the in-bag rows of the requested open leaves — raw values of
    /// every requested owned column, plus labels and bag weights when
    /// `want_meta` — so the tree builder can grow those subtrees
    /// depth-first in memory. Rows are emitted in ascending absolute
    /// row order per leaf; one chunked pass per column through the
    /// store, charged like every other scan. Must be called *before*
    /// the level update that marks the leaves detached (the class list
    /// still maps them to their current ranks).
    pub fn materialize(&self, q: &MaterializeQuery) -> Result<MaterializedLeaves> {
        let _span = crate::span!("materialize", tree = q.tree, depth = q.depth);
        let trees = self.trees.lock().unwrap();
        let state = trees
            .get(&q.tree)
            .ok_or_else(|| anyhow::anyhow!("splitter {}: unknown tree {}", self.id, q.tree))?;
        let cl = &state.class_list;
        let counts = cl.histogram();
        // Rank → output slot (position in q.ranks).
        let mut slot_of = vec![usize::MAX; counts.len()];
        for (s, &rank) in q.ranks.iter().enumerate() {
            anyhow::ensure!(
                rank > 0 && (rank as usize) < counts.len(),
                "splitter {}: materialize rank {rank} out of range",
                self.id
            );
            slot_of[rank as usize] = s;
        }

        // One class-list pass collecting each leaf's in-bag absolute
        // rows, ascending (codes > 0 are in-bag by construction).
        let mut rows: Vec<Vec<u32>> = vec![Vec::new(); q.ranks.len()];
        for i in 0..self.num_rows() {
            let h = cl.get(i) as usize;
            if h != 0 && slot_of[h] != usize::MAX {
                rows[slot_of[h]].push(i as u32);
            }
        }

        // One chunked pass per requested column; each pass fills every
        // leaf's value vector by merging the sorted row lists against
        // the chunk's absolute row range.
        let col_values = store::run_scans(self.cfg.scan_threads, q.columns.len(), |k| {
            let j = q.columns[k];
            let mut nums: Vec<Vec<f32>> = vec![Vec::new(); rows.len()];
            let mut cats: Vec<Vec<u32>> = vec![Vec::new(); rows.len()];
            let mut cursor = vec![0usize; rows.len()];
            self.storage.scan_raw(j, &mut |base, chunk| {
                let lo = base as u32;
                let hi = lo + chunk.len() as u32;
                for (s, rs) in rows.iter().enumerate() {
                    let c = &mut cursor[s];
                    while *c < rs.len() && rs[*c] < hi {
                        let off = (rs[*c] - lo) as usize;
                        match chunk {
                            RawChunk::Numerical(v) => nums[s].push(v[off]),
                            RawChunk::Categorical(v) => cats[s].push(v[off]),
                        }
                        *c += 1;
                    }
                }
                Ok(())
            })?;
            Ok(match self.schema.columns[j].ctype {
                ColumnType::Numerical => nums.into_iter().map(MaterializedColumn::Num).collect(),
                ColumnType::Categorical { arity } => cats
                    .into_iter()
                    .map(|values| MaterializedColumn::Cat { arity, values })
                    .collect::<Vec<_>>(),
            })
        })?;

        // Transpose column-major scan results into per-leaf column sets
        // (moves, no clones — leaf vectors can be large).
        let mut per_leaf: Vec<Vec<MaterializedColumn>> = rows
            .iter()
            .map(|_| Vec::with_capacity(q.columns.len()))
            .collect();
        for col in col_values {
            for (s, v) in col.into_iter().enumerate() {
                per_leaf[s].push(v);
            }
        }
        let leaves = rows
            .iter()
            .zip(per_leaf)
            .map(|(rs, columns)| MaterializedLeaf {
                rows: rs.len() as u64,
                labels: if q.want_meta {
                    rs.iter().map(|&i| self.labels[i as usize]).collect()
                } else {
                    Vec::new()
                },
                bags: if q.want_meta {
                    rs.iter().map(|&i| state.bag_weights[i as usize]).collect()
                } else {
                    Vec::new()
                },
                columns,
            })
            .collect();
        Ok(MaterializedLeaves { leaves })
    }

    /// A resident subtree finished growing on the tree builder.
    /// Observability only — the class list already dropped those rows
    /// when the Detached level update landed — but an unknown tree is
    /// still an error so a restarted worker triggers replay recovery
    /// before the next real RPC mis-decodes state.
    pub fn subtree_done(&self, d: &SubtreeDone) -> Result<()> {
        let trees = self.trees.lock().unwrap();
        anyhow::ensure!(
            trees.contains_key(&d.tree),
            "splitter {}: unknown tree {}",
            self.id,
            d.tree
        );
        crate::telemetry::counter("drf_splitter_subtrees_done_total").add(1);
        Ok(())
    }

    /// Alg. 2 step 7: apply the broadcast level update to the local
    /// class list (identical logic on every worker and the tree builder).
    ///
    /// Idempotent under duplicate delivery: an update for a depth this
    /// tree already passed is acknowledged without re-applying (the
    /// cluster transport re-issues in-flight requests after a
    /// reconnect, so a worker that never lost state can legitimately
    /// see the same update twice). A *gap* is still an error — it
    /// means state was lost and the caller must replay from scratch.
    pub fn apply_level_update(&self, u: &LevelUpdate) -> Result<()> {
        let mut trees = self.trees.lock().unwrap();
        let state = trees
            .get_mut(&u.tree)
            .ok_or_else(|| anyhow::anyhow!("splitter {}: unknown tree {}", self.id, u.tree))?;
        if u.depth < state.next_depth {
            return Ok(()); // duplicate delivery — already applied
        }
        anyhow::ensure!(
            u.depth == state.next_depth,
            "splitter {}: level update out of order (got depth {}, expected {})",
            self.id,
            u.depth,
            state.next_depth
        );
        state.class_list = apply_update_to_class_list(&state.class_list, u)?;
        state.next_depth = u.depth + 1;
        // The level round is over: drop the presorted views cached for
        // the XLA path (the cache is scoped to one level round so a
        // deep disk-backed run never holds more than one level's worth
        // of materialized columns).
        state.sorted_cache.lock().unwrap().clear();

        // SPRINT-style adaptive pruning (paper §3): once the closed
        // fraction crosses the threshold, rebuild per-tree attribute
        // lists containing only samples still in open leaves — one
        // chunked filter pass per owned numerical column (parallel up
        // to `scan_threads`).
        if let PruneMode::Adaptive { threshold } = self.cfg.prune {
            let open = state.class_list.iter_open().count();
            let closed_frac = 1.0 - open as f64 / self.num_rows().max(1) as f64;
            if closed_frac >= threshold {
                let cl = &state.class_list;
                let cols: Vec<usize> = self
                    .storage
                    .columns()
                    .into_iter()
                    .filter(|&j| self.schema.columns[j].ctype.is_numerical())
                    .collect();
                let kept_lists = store::run_scans(self.cfg.scan_threads, cols.len(), |k| {
                    let mut kept: Vec<SortedEntry> = Vec::new();
                    self.storage.scan_sorted(cols[k], &mut |chunk| {
                        kept.extend(
                            chunk
                                .iter()
                                .filter(|e| cl.get(e.sample as usize) != 0)
                                .copied(),
                        );
                        Ok(())
                    })?;
                    Ok(kept)
                })?;
                let mut pruned = BTreeMap::new();
                for (j, kept) in cols.into_iter().zip(kept_lists) {
                    // Pruning is a write pass (Sprint's cost).
                    self.stats.add_disk_write(kept.len() as u64 * 8);
                    self.stats.add_write_pass();
                    pruned.insert(j, kept);
                }
                state.pruned_sorted = Some(pruned);
            }
        }
        Ok(())
    }

    /// Drop a finished tree's state.
    pub fn finish_tree(&self, tree: u32) {
        self.trees.lock().unwrap().remove(&tree);
    }

    /// Current class-list memory in bits (for the memory benches).
    pub fn class_list_bits(&self) -> u64 {
        self.trees
            .lock()
            .unwrap()
            .values()
            .map(|s| s.class_list.memory_bits())
            .sum()
    }
}

/// Pure function: the class-list transition of one depth level. Used by
/// splitters *and* the tree builder so the transition is provably
/// identical (unit-tested against hand-built examples, property-tested
/// in `tests/`).
pub fn apply_update_to_class_list(cl: &ClassList, u: &LevelUpdate) -> Result<ClassList> {
    let old_open = cl.num_open() as usize;
    anyhow::ensure!(
        u.outcomes.len() == old_open,
        "update has {} outcomes for {} open leaves",
        u.outcomes.len(),
        old_open
    );
    // New rank of each (old leaf, side): ranks assigned to open children
    // in outcome order, left before right.
    let mut left_rank = vec![0u32; old_open];
    let mut right_rank = vec![0u32; old_open];
    let mut next = 0u32;
    for (r, outcome) in u.outcomes.iter().enumerate() {
        if let super::messages::LeafOutcome::Split {
            left_open,
            right_open,
            ..
        } = outcome
        {
            if *left_open {
                next += 1;
                left_rank[r] = next;
            }
            if *right_open {
                next += 1;
                right_rank[r] = next;
            }
        }
    }
    // Validate bitmap lengths against the actual per-leaf populations
    // before touching any state (the bitmap is indexed by position among
    // the leaf's samples).
    let leaf_counts = cl.histogram();
    for (r, outcome) in u.outcomes.iter().enumerate() {
        if let super::messages::LeafOutcome::Split { bitmap, .. } = outcome {
            anyhow::ensure!(
                bitmap.len() as u64 == leaf_counts[r + 1],
                "bitmap length {} != {} samples in leaf rank {}",
                bitmap.len(),
                leaf_counts[r + 1],
                r + 1
            );
        }
    }
    // Per-leaf position counters into the bitmaps.
    let mut pos = vec![0usize; old_open];
    let new_cl = cl.rewrite(next, |_i, old| {
        if old == 0 {
            return 0;
        }
        let r = (old - 1) as usize;
        match &u.outcomes[r] {
            // Detached ≡ Closed for the class list: the rows leave the
            // distributed frontier (their subtree grows on the builder
            // from the materialized copy).
            super::messages::LeafOutcome::Closed | super::messages::LeafOutcome::Detached => 0,
            super::messages::LeafOutcome::Split { bitmap, .. } => {
                let p = pos[r];
                pos[r] += 1;
                if bitmap.get(p) {
                    left_rank[r]
                } else {
                    right_rank[r]
                }
            }
        }
    });
    Ok(new_cl)
}

/// Build a splitter's in-memory store from a full dataset and its
/// column assignment (presorting numerical columns on the way — the
/// dataset-preparation phase of §2.1).
pub fn memory_storage_for(ds: &crate::data::Dataset, columns: &[usize]) -> Arc<dyn ColumnStore> {
    crate::data::store::mem_store_for(ds, columns)
}

/// Write a splitter's columns to DRFC v1 files under `dir` and return
/// the disk store (used by the disk-mode benches/tests), prefetching
/// `prefetch_chunks` ahead per scan (0 = synchronous).
pub fn disk_storage_for(
    ds: &crate::data::Dataset,
    columns: &[usize],
    dir: &std::path::Path,
    stats: IoStats,
    prefetch_chunks: usize,
) -> Result<Arc<dyn ColumnStore>> {
    crate::data::store::disk_store_for(ds, columns, dir, stats, prefetch_chunks)
}

/// Write a splitter's columns to chunked DRFC v2 files under `dir` and
/// return the disk store.
pub fn disk_v2_storage_for(
    ds: &crate::data::Dataset,
    columns: &[usize],
    dir: &std::path::Path,
    chunk_rows: u32,
    stats: IoStats,
    prefetch_chunks: usize,
) -> Result<Arc<dyn ColumnStore>> {
    crate::data::store::disk_v2_store_for(ds, columns, dir, chunk_rows, stats, prefetch_chunks)
}

/// Open a splitter's columns over the `drf objstore` at `addr` — every
/// scan becomes chunk-aligned byte-range reads over the wire
/// ([`crate::data::remote::RemoteStore`]), prefetching
/// `prefetch_chunks` range reads ahead (0 = synchronous). The objstore
/// must serve a dataset directory layout (`col_<j>.drfc`, plus
/// `col_<j>.sorted.drfc` for numerical columns).
pub fn remote_storage_for(
    addr: &str,
    schema: &crate::data::Schema,
    columns: &[usize],
    stats: IoStats,
    prefetch_chunks: usize,
) -> Result<Arc<dyn ColumnStore>> {
    crate::data::remote::remote_store_for(addr, schema, columns, stats, prefetch_chunks)
}

/// Write a splitter's columns as chunked DRFC v2 files under `dir` and
/// memory-map them — scans borrow chunk slices straight from the
/// mapping ([`crate::data::mmap::MmapStore`]).
pub fn mmap_storage_for(
    ds: &crate::data::Dataset,
    columns: &[usize],
    dir: &std::path::Path,
    chunk_rows: u32,
    stats: IoStats,
) -> Result<Arc<dyn ColumnStore>> {
    crate::data::store::mmap_store_for(ds, columns, dir, chunk_rows, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::{LeafInfo, LeafOutcome};
    use crate::data::synthetic::{Family, SyntheticSpec};
    use crate::rng::BaggingMode;

    fn test_cfg() -> SplitterConfig {
        SplitterConfig {
            seed: 7,
            bagger: Bagger::new(7, BaggingMode::None),
            feature_sampling: FeatureSampling::All,
            num_candidates: 8,
            score_kind: ScoreKind::Gini,
            prune: PruneMode::Never,
            scan_threads: 1,
            split_search: SplitSearch::Exact,
        }
    }

    fn make_splitter(n: usize) -> (SplitterCore, crate::data::Dataset) {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, n, 4, 42).generate();
        let storage = memory_storage_for(&ds, &[0, 1, 2, 3]);
        let labels = Arc::new(ds.labels().to_vec());
        let core = SplitterCore::new(
            0,
            ds.schema().clone(),
            storage,
            labels,
            test_cfg(),
            IoStats::new(),
        );
        (core, ds)
    }

    #[test]
    fn root_stats_match_dataset() {
        let (s, ds) = make_splitter(500);
        s.start_tree(0);
        assert_eq!(s.root_stats(0), ds.class_counts());
    }

    #[test]
    fn find_splits_returns_per_leaf() {
        let (s, ds) = make_splitter(400);
        s.start_tree(0);
        let q = SupersplitQuery {
            tree: 0,
            depth: 0,
            leaves: vec![LeafInfo {
                node_id: 0,
                detached: false,
                totals: ds.class_counts(),
            }],
            assigned_columns: vec![0, 1, 2, 3],
        };
        let p = s.find_splits(&q).unwrap();
        assert_eq!(p.splits.len(), 1);
        // XOR root: informative features alone give ~0 gain but finite-
        // sample noise yields *some* candidate; just check shape & no
        // panic, and that any candidate has positive gain.
        if let Some(c) = &p.splits[0] {
            assert!(c.gain > 0.0);
        }
    }

    #[test]
    fn parallel_scans_match_serial() {
        // The scan pool must never change any RPC answer: same query,
        // scan_threads 1 vs 4, memory and disk stores.
        let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 600, 6, 9).generate();
        let labels = Arc::new(ds.labels().to_vec());
        let dir = crate::util::tempdir().unwrap();
        let q = SupersplitQuery {
            tree: 0,
            depth: 0,
            leaves: vec![LeafInfo {
                node_id: 0,
                detached: false,
                totals: ds.class_counts(),
            }],
            assigned_columns: vec![0, 1, 2, 3, 4, 5],
        };
        let eq = EvalQuery {
            tree: 0,
            depth: 0,
            conditions: vec![(
                1,
                Condition::NumLe {
                    feature: 2,
                    threshold: 0.5,
                },
            )],
        };
        let mut answers = Vec::new();
        for threads in [1usize, 4] {
            for disk in [false, true] {
                let storage = if disk {
                    let sub = dir.path().join(format!("s{threads}_{disk}"));
                    std::fs::create_dir_all(&sub).unwrap();
                    disk_storage_for(&ds, &[0, 1, 2, 3, 4, 5], &sub, IoStats::new(), 0).unwrap()
                } else {
                    memory_storage_for(&ds, &[0, 1, 2, 3, 4, 5])
                };
                let cfg = SplitterConfig {
                    scan_threads: threads,
                    ..test_cfg()
                };
                let s = SplitterCore::new(
                    0,
                    ds.schema().clone(),
                    storage,
                    labels.clone(),
                    cfg,
                    IoStats::new(),
                );
                s.start_tree(0);
                answers.push((s.find_splits(&q).unwrap(), s.eval_conditions(&eq).unwrap()));
            }
        }
        for a in &answers[1..] {
            assert_eq!(answers[0].0, a.0, "find_splits must be scan-invariant");
            assert_eq!(answers[0].1, a.1, "eval_conditions must be scan-invariant");
        }
    }

    #[test]
    fn eval_bitmap_counts_in_bag_leaf_samples() {
        let (s, _ds) = make_splitter(100);
        s.start_tree(0);
        let q = EvalQuery {
            tree: 0,
            depth: 0,
            conditions: vec![(
                1,
                Condition::NumLe {
                    feature: 0,
                    threshold: 0.5,
                },
            )],
        };
        let r = s.eval_conditions(&q).unwrap();
        assert_eq!(r.bitmaps.len(), 1);
        let (rank, bm) = &r.bitmaps[0];
        assert_eq!(*rank, 1);
        // BaggingMode::None -> all 100 samples in bag and at root.
        assert_eq!(bm.len(), 100);
        // Binary features: bit set iff value == 0.0 (i.e. <= 0.5).
        assert!(bm.count_ones() > 20 && bm.count_ones() < 80);
    }

    #[test]
    fn level_update_transition() {
        let (s, _ds) = make_splitter(10);
        s.start_tree(0);
        // Split root: samples alternate left/right; left child open,
        // right child closed.
        let mut bm = Bitmap::with_len(10);
        for i in 0..10 {
            bm.set(i, i % 2 == 0);
        }
        let u = LevelUpdate {
            tree: 0,
            depth: 0,
            outcomes: vec![LeafOutcome::Split {
                bitmap: bm,
                left_open: true,
                right_open: false,
            }],
        };
        s.apply_level_update(&u).unwrap();
        let trees = s.trees.lock().unwrap();
        let cl = &trees.get(&0).unwrap().class_list;
        assert_eq!(cl.num_open(), 1);
        for i in 0..10 {
            assert_eq!(cl.get(i), if i % 2 == 0 { 1 } else { 0 });
        }
    }

    #[test]
    fn duplicate_level_update_is_idempotent() {
        // An at-least-once transport may deliver the same update twice
        // to a worker that never lost state; the second must be a
        // no-op ack, and a *skipped* level must still error.
        let (s, _ds) = make_splitter(10);
        s.start_tree(0);
        let mut bm = Bitmap::with_len(10);
        for i in 0..10 {
            bm.set(i, i % 2 == 0);
        }
        let u = LevelUpdate {
            tree: 0,
            depth: 0,
            outcomes: vec![LeafOutcome::Split {
                bitmap: bm,
                left_open: true,
                right_open: false,
            }],
        };
        s.apply_level_update(&u).unwrap();
        let after_first: Vec<u32> = {
            let trees = s.trees.lock().unwrap();
            let cl = &trees.get(&0).unwrap().class_list;
            (0..10).map(|i| cl.get(i)).collect()
        };
        // Same frame again: accepted, nothing changes.
        s.apply_level_update(&u).unwrap();
        {
            let trees = s.trees.lock().unwrap();
            let cl = &trees.get(&0).unwrap().class_list;
            let after_dup: Vec<u32> = (0..10).map(|i| cl.get(i)).collect();
            assert_eq!(after_first, after_dup, "duplicate must not re-apply");
        }
        // A gap (depth 2 while expecting 1) is state loss, not a dup.
        let skip = LevelUpdate {
            tree: 0,
            depth: 2,
            outcomes: vec![LeafOutcome::Closed],
        };
        let err = s.apply_level_update(&skip).unwrap_err();
        assert!(format!("{err}").contains("out of order"), "{err}");
    }

    #[test]
    fn apply_update_checks_lengths() {
        let cl = ClassList::new_all_root(4);
        let u = LevelUpdate {
            tree: 0,
            depth: 0,
            outcomes: vec![
                LeafOutcome::Closed,
                LeafOutcome::Closed, // too many outcomes
            ],
        };
        assert!(apply_update_to_class_list(&cl, &u).is_err());
        // Bitmap too short.
        let u2 = LevelUpdate {
            tree: 0,
            depth: 0,
            outcomes: vec![LeafOutcome::Split {
                bitmap: Bitmap::with_len(2),
                left_open: true,
                right_open: true,
            }],
        };
        assert!(apply_update_to_class_list(&cl, &u2).is_err());
    }

    #[test]
    fn bagging_excludes_oob_from_class_list() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 1000, 4, 42).generate();
        let storage = memory_storage_for(&ds, &[0, 1]);
        let cfg = SplitterConfig {
            bagger: Bagger::new(7, BaggingMode::Poisson),
            ..test_cfg()
        };
        let s = SplitterCore::new(
            0,
            ds.schema().clone(),
            storage,
            Arc::new(ds.labels().to_vec()),
            cfg,
            IoStats::new(),
        );
        s.start_tree(3);
        let trees = s.trees.lock().unwrap();
        let cl = &trees.get(&3).unwrap().class_list;
        let in_bag = cl.iter_open().count();
        // Poisson(1): ~63.2% in bag.
        assert!((0.55..0.72).contains(&(in_bag as f64 / 1000.0)));
        for (i, _) in cl.iter_open() {
            assert!(cfg.bagger.in_bag(3, i as u64));
        }
    }

    #[test]
    fn disk_storage_roundtrip() {
        let ds = SyntheticSpec::new(Family::LinearCont { informative: 2 }, 200, 3, 1).generate();
        let dir = crate::util::tempdir().unwrap();
        let stats = IoStats::new();
        let storage = disk_storage_for(&ds, &[0, 2], dir.path(), stats.clone(), 0).unwrap();
        let s = SplitterCore::new(
            0,
            ds.schema().clone(),
            storage,
            Arc::new(ds.labels().to_vec()),
            test_cfg(),
            stats.clone(),
        );
        assert_eq!(s.columns_owned(), vec![0, 2]);
        let col = s.storage.read_raw(0).unwrap();
        assert_eq!(col.as_numerical(), ds.column(0).as_numerical());
        let sorted = s.storage.read_sorted(2).unwrap();
        assert_eq!(sorted.as_slice(), ds.column(2).presort().as_slice());
        assert!(stats.disk_read_bytes() > 0);
        assert!(s.storage.read_raw(1).is_err(), "column 1 not owned");
    }
}
