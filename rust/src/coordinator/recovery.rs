//! Worker preemption & recovery.
//!
//! The paper's runs "are performed with a low priority — this shows
//! that the approach remains reliable in spite of interruptions
//! (workers can be killed by tasks with higher priority)" (§4). DRF
//! makes this cheap: a splitter's only mutable state is the per-tree
//! class list, which is a pure fold of (seeded bagging) × (the sequence
//! of LevelUpdates). The tree builder already knows both, so a killed
//! splitter is rebuilt by replaying the update log — no checkpointing,
//! no data movement beyond the original column shard.
//!
//! [`RecoveringPool`] wraps **any** [`SplitterPool`] with exactly that
//! logic: it logs the level updates it broadcasts and, when a call to a
//! splitter fails with "unknown tree" (the signature of lost per-tree
//! state — a preempted in-process core or a cluster worker that was
//! killed and restarted from its shard pack), it replays the log to
//! that one splitter through the pool's single-splitter RPCs
//! ([`SplitterPool::start_tree_on`] /
//! [`SplitterPool::apply_level_update_on`]) and retries. Connection
//! re-establishment itself is the transport's job (the cluster pool
//! reconnects and re-handshakes under the covers); this layer only
//! rebuilds state.
//!
//! A deterministic failure injector drives the resilience tests: after
//! a configurable number of RPCs, a target splitter "dies" (its tree
//! state is wiped via [`SplitterPool::finish_tree_on`] — the column
//! shard itself is immutable input) and the next call to it
//! transparently replays.

use super::messages::{
    EvalQuery, EvalResult, LevelUpdate, MaterializeQuery, MaterializedLeaves, PartialSupersplit,
    SubtreeDone, SupersplitQuery,
};
use super::transport::SplitterPool;
use crate::data::io_stats::IoStats;
use crate::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Deterministic failure plan: kill splitter `s` right before the
/// `rpc_index`-th RPC of the run (global RPC counter).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedFailure {
    pub splitter: usize,
    pub rpc_index: u64,
}

/// A pool wrapper that logs level updates and replays them to recover
/// killed splitters. Generic over the transport: composes with
/// [`super::transport::DirectPool`], [`super::tcp::TcpPool`], and
/// [`crate::cluster::ClusterPool`] alike.
pub struct RecoveringPool<P: SplitterPool> {
    inner: P,
    /// Per-tree ordered log of broadcast level updates.
    log: Mutex<HashMap<u32, Vec<LevelUpdate>>>,
    /// Global RPC counter for deterministic injection.
    rpc_counter: AtomicU64,
    failures: Vec<InjectedFailure>,
    /// Number of recoveries performed (observable by tests).
    recoveries: AtomicU64,
}

impl<P: SplitterPool> RecoveringPool<P> {
    /// Wrap `inner` with replay-based recovery (no injected failures).
    pub fn new(inner: P) -> Self {
        Self::with_failures(inner, Vec::new())
    }

    /// Wrap `inner` and additionally kill splitters per `failures`
    /// (test harness for the recovery path).
    pub fn with_failures(inner: P, failures: Vec<InjectedFailure>) -> Self {
        Self {
            inner,
            log: Mutex::new(HashMap::new()),
            rpc_counter: AtomicU64::new(0),
            failures,
            recoveries: AtomicU64::new(0),
        }
    }

    pub fn recoveries(&self) -> u64 {
        self.recoveries.load(Ordering::SeqCst)
    }

    /// The wrapped transport.
    pub fn inner(&self) -> &P {
        &self.inner
    }

    /// Does this error mean "the splitter lost its per-tree state"?
    fn is_state_loss(e: &anyhow::Error) -> bool {
        format!("{e}").contains("unknown tree")
    }

    /// Kill the target splitter if an injected failure is due.
    fn maybe_crash(&self, splitter: usize, tree: u32) {
        let idx = self.rpc_counter.fetch_add(1, Ordering::SeqCst);
        for f in &self.failures {
            if f.splitter == splitter && f.rpc_index == idx {
                // Simulate preemption: all in-memory per-tree state is
                // lost (the column shard itself is immutable input).
                let _ = self.inner.finish_tree_on(splitter, tree);
            }
        }
    }

    /// Rebuild a splitter's class list for `tree` by replaying the
    /// first `upto` logged updates (`usize::MAX` = the whole log).
    fn replay(&self, splitter: usize, tree: u32, upto: usize) -> Result<()> {
        // Clone the prefix out of the lock: replays over a real network
        // can be slow and must not block concurrent logging.
        let updates: Vec<LevelUpdate> = {
            let log = self.log.lock().unwrap();
            let all = log.get(&tree).map(|v| v.as_slice()).unwrap_or(&[]);
            all[..upto.min(all.len())].to_vec()
        };
        self.inner.start_tree_on(splitter, tree)?;
        for u in &updates {
            self.inner.apply_level_update_on(splitter, u)?;
        }
        self.recoveries.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }

    /// Run an RPC, recovering the splitter once if it lost the tree.
    fn with_recovery<T>(
        &self,
        splitter: usize,
        tree: u32,
        call: impl Fn() -> Result<T>,
    ) -> Result<T> {
        match call() {
            Ok(v) => Ok(v),
            Err(e) if Self::is_state_loss(&e) => {
                self.replay(splitter, tree, usize::MAX)?;
                call()
            }
            Err(e) => Err(e),
        }
    }
}

impl<P: SplitterPool> SplitterPool for RecoveringPool<P> {
    fn num_splitters(&self) -> usize {
        self.inner.num_splitters()
    }

    fn columns_of(&self, splitter: usize) -> Vec<usize> {
        self.inner.columns_of(splitter)
    }

    fn start_tree(&self, tree: u32) -> Result<()> {
        self.log.lock().unwrap().insert(tree, Vec::new());
        self.inner.start_tree(tree)
    }

    fn root_stats(&self, splitter: usize, tree: u32) -> Result<Vec<u64>> {
        self.maybe_crash(splitter, tree);
        // root_stats is stateless w.r.t. the class list; still guarded
        // for uniformity.
        self.with_recovery(splitter, tree, || self.inner.root_stats(splitter, tree))
    }

    fn find_splits(&self, splitter: usize, q: &SupersplitQuery) -> Result<PartialSupersplit> {
        self.maybe_crash(splitter, q.tree);
        self.with_recovery(splitter, q.tree, || self.inner.find_splits(splitter, q))
    }

    fn eval_conditions(&self, splitter: usize, q: &EvalQuery) -> Result<EvalResult> {
        self.maybe_crash(splitter, q.tree);
        self.with_recovery(splitter, q.tree, || self.inner.eval_conditions(splitter, q))
    }

    fn broadcast_level_update(&self, u: &LevelUpdate) -> Result<()> {
        let logged_len = {
            let mut log = self.log.lock().unwrap();
            let entry = log.entry(u.tree).or_default();
            entry.push(u.clone());
            entry.len()
        };
        // A splitter killed just before the broadcast would error here;
        // recover each splitter individually: replay everything logged
        // *before* this update, then apply it.
        for s in 0..self.inner.num_splitters() {
            if let Err(e) = self.inner.apply_level_update_on(s, u) {
                if Self::is_state_loss(&e) {
                    self.replay(s, u.tree, logged_len - 1)?;
                    self.inner.apply_level_update_on(s, u)?;
                } else {
                    return Err(e);
                }
            }
        }
        // The per-splitter applies charged their own bytes/messages;
        // count the logical broadcast (the paper's per-level `Dn` one).
        self.inner.net_stats().add_broadcast_event();
        Ok(())
    }

    fn materialize(&self, splitter: usize, q: &MaterializeQuery) -> Result<MaterializedLeaves> {
        self.maybe_crash(splitter, q.tree);
        // Materialization reads the level-start class list, which the
        // full replay log reconstructs exactly.
        self.with_recovery(splitter, q.tree, || self.inner.materialize(splitter, q))
    }

    fn broadcast_subtree_done(&self, d: &SubtreeDone) -> Result<()> {
        // Not logged: SubtreeDone carries no class-list state, so a
        // replayed splitter needs the log without it. A splitter that
        // lost the tree is replayed and then re-notified.
        for s in 0..self.inner.num_splitters() {
            if let Err(e) = self.inner.broadcast_subtree_done_on(s, d) {
                if Self::is_state_loss(&e) {
                    self.replay(s, d.tree, usize::MAX)?;
                    self.inner.broadcast_subtree_done_on(s, d)?;
                } else {
                    return Err(e);
                }
            }
        }
        self.inner.net_stats().add_broadcast_event();
        Ok(())
    }

    fn broadcast_subtree_done_on(&self, splitter: usize, d: &SubtreeDone) -> Result<()> {
        self.inner.broadcast_subtree_done_on(splitter, d)
    }

    fn finish_tree(&self, tree: u32) -> Result<()> {
        self.log.lock().unwrap().remove(&tree);
        self.inner.finish_tree(tree)
    }

    fn net_stats(&self) -> IoStats {
        self.inner.net_stats()
    }

    fn start_tree_on(&self, splitter: usize, tree: u32) -> Result<()> {
        self.inner.start_tree_on(splitter, tree)
    }

    fn apply_level_update_on(&self, splitter: usize, u: &LevelUpdate) -> Result<()> {
        self.inner.apply_level_update_on(splitter, u)
    }

    fn finish_tree_on(&self, splitter: usize, tree: u32) -> Result<()> {
        self.inner.finish_tree_on(splitter, tree)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ForestParams, PruneMode};
    use crate::coordinator::splitter::{memory_storage_for, SplitterConfig, SplitterCore};
    use crate::coordinator::topology::Topology;
    use crate::coordinator::transport::DirectPool;
    use crate::coordinator::tree_builder::TreeBuilderCore;
    use crate::data::synthetic::{Family, SyntheticSpec};
    use crate::rng::{Bagger, BaggingMode};
    use std::sync::Arc;

    fn build_cores(
        ds: &crate::data::Dataset,
        params: &ForestParams,
        w: usize,
    ) -> Vec<Arc<SplitterCore>> {
        let topo = Topology::new(
            ds.num_features(),
            &crate::config::TopologyParams {
                num_splitters: Some(w),
                ..Default::default()
            },
        );
        let labels = Arc::new(ds.labels().to_vec());
        let cfg = SplitterConfig {
            seed: params.seed,
            bagger: Bagger::new(params.seed, params.bagging),
            feature_sampling: params.feature_sampling,
            num_candidates: params.candidates_for(ds.num_features()),
            score_kind: params.score_kind,
            prune: PruneMode::Never,
            scan_threads: 1,
            split_search: crate::config::SplitSearch::Exact,
        };
        (0..topo.num_splitters())
            .map(|s| {
                Arc::new(SplitterCore::new(
                    s,
                    ds.schema().clone(),
                    memory_storage_for(ds, &topo.columns_of(s)),
                    labels.clone(),
                    cfg,
                    IoStats::new(),
                ))
            })
            .collect()
    }

    fn build_pool(ds: &crate::data::Dataset, params: &ForestParams, w: usize) -> DirectPool {
        DirectPool::new(build_cores(ds, params, w), 0)
    }

    #[test]
    fn training_survives_injected_preemptions() {
        let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 600, 6, 5).generate();
        let params = ForestParams {
            num_trees: 1,
            max_depth: 6,
            bagging: BaggingMode::Poisson,
            seed: 31,
            ..Default::default()
        };
        let topo = Topology::new(
            ds.num_features(),
            &crate::config::TopologyParams {
                num_splitters: Some(3),
                ..Default::default()
            },
        );

        // Reference: no failures.
        let clean_pool = build_pool(&ds, &params, 3);
        let builder = TreeBuilderCore::new(&clean_pool, &topo, &params, ds.num_features());
        let (reference, _) = builder.build_tree(0).unwrap();

        // Kill splitter 1 several times through the run.
        let failing = RecoveringPool::with_failures(
            build_pool(&ds, &params, 3),
            vec![
                InjectedFailure {
                    splitter: 1,
                    rpc_index: 3,
                },
                InjectedFailure {
                    splitter: 0,
                    rpc_index: 9,
                },
                InjectedFailure {
                    splitter: 2,
                    rpc_index: 15,
                },
            ],
        );
        let builder = TreeBuilderCore::new(&failing, &topo, &params, ds.num_features());
        let (recovered, _) = builder.build_tree(0).unwrap();
        assert!(
            failing.recoveries() >= 1,
            "failures must actually have triggered recovery"
        );
        assert_eq!(reference, recovered, "recovery must preserve exactness");
    }

    #[test]
    fn crash_during_broadcast_recovers() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 300, 4, 5).generate();
        let params = ForestParams {
            num_trees: 1,
            max_depth: 5,
            bagging: BaggingMode::None,
            seed: 4,
            ..Default::default()
        };
        let topo = Topology::new(
            ds.num_features(),
            &crate::config::TopologyParams {
                num_splitters: Some(2),
                ..Default::default()
            },
        );
        let clean_pool = build_pool(&ds, &params, 2);
        let builder = TreeBuilderCore::new(&clean_pool, &topo, &params, ds.num_features());
        let (reference, _) = builder.build_tree(0).unwrap();

        // Many injection points: some land right before broadcasts.
        let failures: Vec<InjectedFailure> = (0..30)
            .map(|k| InjectedFailure {
                splitter: (k % 2) as usize,
                rpc_index: k as u64,
            })
            .collect();
        let failing = RecoveringPool::with_failures(build_pool(&ds, &params, 2), failures);
        let builder = TreeBuilderCore::new(&failing, &topo, &params, ds.num_features());
        let (recovered, _) = builder.build_tree(0).unwrap();
        assert_eq!(reference, recovered);
        assert!(failing.recoveries() >= 2);
    }

    #[test]
    fn recovery_composes_with_tcp_transport() {
        // The generic wrapper must replay over real sockets too: wrap a
        // TcpPool whose servers hold the cores, inject state loss, and
        // require the exact reference tree back.
        use crate::coordinator::tcp::{SplitterServer, TcpPool};

        let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 400, 6, 7).generate();
        let params = ForestParams {
            num_trees: 1,
            max_depth: 5,
            bagging: BaggingMode::Poisson,
            seed: 23,
            ..Default::default()
        };
        let topo = Topology::new(
            ds.num_features(),
            &crate::config::TopologyParams {
                num_splitters: Some(3),
                ..Default::default()
            },
        );

        let clean_pool = build_pool(&ds, &params, 3);
        let builder = TreeBuilderCore::new(&clean_pool, &topo, &params, ds.num_features());
        let (reference, _) = builder.build_tree(0).unwrap();

        let servers: Vec<SplitterServer> = build_cores(&ds, &params, 3)
            .into_iter()
            .map(|c| SplitterServer::spawn(c).unwrap())
            .collect();
        let addrs: Vec<_> = servers.iter().map(|s| s.addr()).collect();
        let columns: Vec<_> = (0..topo.num_splitters())
            .map(|s| topo.columns_of(s))
            .collect();
        let tcp = TcpPool::connect(&addrs, columns).unwrap();
        // Cover every splitter at the chosen indices so the kills fire
        // regardless of which splitter those RPCs target.
        let failures: Vec<InjectedFailure> = (0..3)
            .flat_map(|s| {
                [3u64, 10].map(|rpc_index| InjectedFailure {
                    splitter: s,
                    rpc_index,
                })
            })
            .collect();
        let failing = RecoveringPool::with_failures(tcp, failures);
        let builder = TreeBuilderCore::new(&failing, &topo, &params, ds.num_features());
        let (recovered, _) = builder.build_tree(0).unwrap();
        assert!(
            failing.recoveries() >= 1,
            "TCP-backed recovery must actually fire"
        );
        assert_eq!(
            reference, recovered,
            "replay over TCP must preserve exactness"
        );
        assert!(failing.net_stats().net_bytes() > 0);
    }
}
