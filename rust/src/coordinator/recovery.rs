//! Worker preemption & recovery.
//!
//! The paper's runs "are performed with a low priority — this shows
//! that the approach remains reliable in spite of interruptions
//! (workers can be killed by tasks with higher priority)" (§4). DRF
//! makes this cheap: a splitter's only mutable state is the per-tree
//! class list, which is a pure fold of (seeded bagging) × (the sequence
//! of LevelUpdates). The tree builder already knows both, so a killed
//! splitter is rebuilt by replaying the update log — no checkpointing,
//! no data movement beyond the original column shard.
//!
//! [`RecoveringPool`] wraps a pool with exactly that logic, plus a
//! deterministic failure injector used by the resilience tests: after a
//! configurable number of RPCs, a target splitter "dies" (its tree
//! state is wiped) and the next call to it transparently replays.

use super::messages::{EvalQuery, EvalResult, LevelUpdate, PartialSupersplit, SupersplitQuery};
use super::transport::{DirectPool, SplitterPool};
use crate::data::io_stats::IoStats;
use crate::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Deterministic failure plan: kill splitter `s` right before the
/// `rpc_index`-th RPC of the run (global RPC counter).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedFailure {
    pub splitter: usize,
    pub rpc_index: u64,
}

/// A pool wrapper that logs level updates and replays them to recover
/// killed splitters.
pub struct RecoveringPool {
    inner: DirectPool,
    /// Per-tree ordered log of broadcast level updates.
    log: Mutex<HashMap<u32, Vec<LevelUpdate>>>,
    /// Global RPC counter for deterministic injection.
    rpc_counter: AtomicU64,
    failures: Vec<InjectedFailure>,
    /// Number of recoveries performed (observable by tests).
    recoveries: AtomicU64,
}

impl RecoveringPool {
    pub fn new(inner: DirectPool, failures: Vec<InjectedFailure>) -> Self {
        Self {
            inner,
            log: Mutex::new(HashMap::new()),
            rpc_counter: AtomicU64::new(0),
            failures,
            recoveries: AtomicU64::new(0),
        }
    }

    pub fn recoveries(&self) -> u64 {
        self.recoveries.load(Ordering::SeqCst)
    }

    /// Kill the target splitter if an injected failure is due.
    fn maybe_crash(&self, splitter: usize, tree: u32) {
        let idx = self.rpc_counter.fetch_add(1, Ordering::SeqCst);
        for f in &self.failures {
            if f.splitter == splitter && f.rpc_index == idx {
                // Simulate preemption: all in-memory per-tree state is
                // lost (the column shard itself is immutable input).
                self.inner.splitter(splitter).finish_tree(tree);
            }
        }
    }

    /// Rebuild a splitter's class list for `tree` by replaying the log.
    fn recover(&self, splitter: usize, tree: u32) -> Result<()> {
        let log = self.log.lock().unwrap();
        let updates = log.get(&tree).map(|v| v.as_slice()).unwrap_or(&[]);
        let s = self.inner.splitter(splitter);
        s.start_tree(tree);
        for u in updates {
            s.apply_level_update(u)?;
        }
        self.recoveries.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }

    /// Run an RPC, recovering the splitter once if it lost the tree.
    fn with_recovery<T>(
        &self,
        splitter: usize,
        tree: u32,
        call: impl Fn() -> Result<T>,
    ) -> Result<T> {
        match call() {
            Ok(v) => Ok(v),
            Err(e) if format!("{e}").contains("unknown tree") => {
                self.recover(splitter, tree)?;
                call()
            }
            Err(e) => Err(e),
        }
    }
}

impl SplitterPool for RecoveringPool {
    fn num_splitters(&self) -> usize {
        self.inner.num_splitters()
    }

    fn columns_of(&self, splitter: usize) -> Vec<usize> {
        self.inner.columns_of(splitter)
    }

    fn start_tree(&self, tree: u32) -> Result<()> {
        self.log.lock().unwrap().insert(tree, Vec::new());
        self.inner.start_tree(tree)
    }

    fn root_stats(&self, splitter: usize, tree: u32) -> Result<Vec<u64>> {
        self.maybe_crash(splitter, tree);
        // root_stats is stateless w.r.t. the class list; still guarded
        // for uniformity.
        self.with_recovery(splitter, tree, || self.inner.root_stats(splitter, tree))
    }

    fn find_splits(&self, splitter: usize, q: &SupersplitQuery) -> Result<PartialSupersplit> {
        self.maybe_crash(splitter, q.tree);
        self.with_recovery(splitter, q.tree, || self.inner.find_splits(splitter, q))
    }

    fn eval_conditions(&self, splitter: usize, q: &EvalQuery) -> Result<EvalResult> {
        self.maybe_crash(splitter, q.tree);
        self.with_recovery(splitter, q.tree, || self.inner.eval_conditions(splitter, q))
    }

    fn broadcast_level_update(&self, u: &LevelUpdate) -> Result<()> {
        self.log
            .lock()
            .unwrap()
            .entry(u.tree)
            .or_default()
            .push(u.clone());
        // A splitter killed just before the broadcast would error here;
        // recover each splitter individually.
        for s in 0..self.inner.num_splitters() {
            let res = self.inner.splitter(s).apply_level_update(u);
            if let Err(e) = res {
                if format!("{e}").contains("unknown tree") {
                    // Replay everything *before* this update, then apply it.
                    {
                        let log = self.log.lock().unwrap();
                        let updates = log.get(&u.tree).map(|v| v.as_slice()).unwrap_or(&[]);
                        let sp = self.inner.splitter(s);
                        sp.start_tree(u.tree);
                        for prev in &updates[..updates.len() - 1] {
                            sp.apply_level_update(prev)?;
                        }
                        sp.apply_level_update(u)?;
                    }
                    self.recoveries.fetch_add(1, Ordering::SeqCst);
                } else {
                    return Err(e);
                }
            }
        }
        // Network accounting mirrors the inner broadcast.
        self.inner.net_stats().add_broadcast(
            u.wire_bytes(),
            self.inner.num_splitters() as u64,
        );
        Ok(())
    }

    fn finish_tree(&self, tree: u32) -> Result<()> {
        self.log.lock().unwrap().remove(&tree);
        self.inner.finish_tree(tree)
    }

    fn net_stats(&self) -> IoStats {
        self.inner.net_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ForestParams, PruneMode};
    use crate::coordinator::splitter::{memory_storage_for, SplitterConfig, SplitterCore};
    use crate::coordinator::topology::Topology;
    use crate::coordinator::tree_builder::TreeBuilderCore;
    use crate::data::synthetic::{Family, SyntheticSpec};
    use crate::rng::{Bagger, BaggingMode};
    use std::sync::Arc;

    fn build_pool(ds: &crate::data::Dataset, params: &ForestParams, w: usize) -> DirectPool {
        let topo = Topology::new(
            ds.num_features(),
            &crate::config::TopologyParams {
                num_splitters: Some(w),
                ..Default::default()
            },
        );
        let labels = Arc::new(ds.labels().to_vec());
        let cfg = SplitterConfig {
            seed: params.seed,
            bagger: Bagger::new(params.seed, params.bagging),
            feature_sampling: params.feature_sampling,
            num_candidates: params.candidates_for(ds.num_features()),
            score_kind: params.score_kind,
            prune: PruneMode::Never,
            scan_threads: 1,
        };
        let splitters = (0..topo.num_splitters())
            .map(|s| {
                Arc::new(SplitterCore::new(
                    s,
                    ds.schema().clone(),
                    memory_storage_for(ds, &topo.columns_of(s)),
                    labels.clone(),
                    cfg,
                    IoStats::new(),
                ))
            })
            .collect();
        DirectPool::new(splitters, 0)
    }

    #[test]
    fn training_survives_injected_preemptions() {
        let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 600, 6, 5).generate();
        let params = ForestParams {
            num_trees: 1,
            max_depth: 6,
            bagging: BaggingMode::Poisson,
            seed: 31,
            ..Default::default()
        };
        let topo = Topology::new(
            ds.num_features(),
            &crate::config::TopologyParams {
                num_splitters: Some(3),
                ..Default::default()
            },
        );

        // Reference: no failures.
        let clean_pool = build_pool(&ds, &params, 3);
        let builder = TreeBuilderCore::new(&clean_pool, &topo, &params, ds.num_features());
        let (reference, _) = builder.build_tree(0).unwrap();

        // Kill splitter 1 several times through the run.
        let failing = RecoveringPool::new(
            build_pool(&ds, &params, 3),
            vec![
                InjectedFailure {
                    splitter: 1,
                    rpc_index: 3,
                },
                InjectedFailure {
                    splitter: 0,
                    rpc_index: 9,
                },
                InjectedFailure {
                    splitter: 2,
                    rpc_index: 15,
                },
            ],
        );
        let builder = TreeBuilderCore::new(&failing, &topo, &params, ds.num_features());
        let (recovered, _) = builder.build_tree(0).unwrap();
        assert!(
            failing.recoveries() >= 1,
            "failures must actually have triggered recovery"
        );
        assert_eq!(reference, recovered, "recovery must preserve exactness");
    }

    #[test]
    fn crash_during_broadcast_recovers() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 300, 4, 5).generate();
        let params = ForestParams {
            num_trees: 1,
            max_depth: 5,
            bagging: BaggingMode::None,
            seed: 4,
            ..Default::default()
        };
        let topo = Topology::new(
            ds.num_features(),
            &crate::config::TopologyParams {
                num_splitters: Some(2),
                ..Default::default()
            },
        );
        let clean_pool = build_pool(&ds, &params, 2);
        let builder = TreeBuilderCore::new(&clean_pool, &topo, &params, ds.num_features());
        let (reference, _) = builder.build_tree(0).unwrap();

        // Many injection points: some land right before broadcasts.
        let failures: Vec<InjectedFailure> = (0..30)
            .map(|k| InjectedFailure {
                splitter: (k % 2) as usize,
                rpc_index: k as u64,
            })
            .collect();
        let failing = RecoveringPool::new(build_pool(&ds, &params, 2), failures);
        let builder = TreeBuilderCore::new(&failing, &topo, &params, ds.num_features());
        let (recovered, _) = builder.build_tree(0).unwrap();
        assert_eq!(reference, recovered);
        assert!(failing.recoveries() >= 2);
    }
}
