//! The DRF coordinator (paper §2) — the system contribution.
//!
//! Three worker roles communicate through an accounted transport:
//!
//! * **splitters** ([`splitter`]) own column shards and search/evaluate
//!   splits;
//! * **tree builders** ([`tree_builder`]) each drive one tree
//!   depth-level-by-depth-level (Alg. 2);
//! * the **manager** ([`manager`]) owns the fleet, runs tree builders
//!   (in parallel for RF), and collects finished trees.
//!
//! [`messages`] defines the protocol with exact wire-size accounting,
//! [`topology`] the column→splitter ownership (with d-redundancy and the
//! per-level balanced assignment of §3.2), and [`transport`] the
//! `SplitterPool` RPC surface.

pub mod manager;
pub mod messages;
pub mod recovery;
pub mod splitter;
pub mod tcp;
pub mod topology;
pub mod transport;
pub mod wire;
pub mod tree_builder;

pub use manager::{Manager, TrainReport, TreeReport};
pub use messages::{Bitmap, LeafOutcome, LevelUpdate};
pub use topology::Topology;
pub use transport::{DirectPool, SplitterPool};
pub use tree_builder::{LevelStats, TreeBuilderCore};
