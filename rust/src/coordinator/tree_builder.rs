//! The tree builder worker — Alg. 2 of the paper.
//!
//! A tree builder holds the structure of one decision tree in training
//! and coordinates the splitters; it has **no access to the dataset**.
//! Trees grow depth-level by depth-level: one supersplit query round,
//! one condition-evaluation round, and one class-list broadcast per
//! level — never per node.

use super::messages::{EvalQuery, LeafInfo, LeafOutcome, LevelUpdate, SupersplitQuery};
use super::topology::Topology;
use super::transport::SplitterPool;
use crate::config::ForestParams;
use crate::metrics::Stopwatch;
use crate::rng::FeatureSampler;
use crate::splits::scorer::pick_best;
use crate::splits::SplitCandidate;
use crate::tree::Tree;
use crate::Result;

/// Per-depth-level statistics (feeds the paper's Figure 3 and the
/// complexity benches).
#[derive(Debug, Clone)]
pub struct LevelStats {
    pub depth: u32,
    /// Wall-clock seconds spent on this level.
    pub seconds: f64,
    /// Open leaves entering the level.
    pub open_before: u32,
    /// Open leaves after the level's splits/closes.
    pub open_after: u32,
    /// Leaves that split this level.
    pub num_splits: u32,
    /// Leaves that closed this level.
    pub num_closed: u32,
    /// Distinct candidate columns across all leaves (paper's `m''`).
    pub m_double_prime: usize,
    /// Max columns assigned to one splitter this level (paper's `Z`).
    pub z_max_load: usize,
    /// Network bytes moved during this level.
    pub net_bytes: u64,
    /// Bagged sample weight still in open leaves entering this level.
    pub open_weight: u64,
    /// Seconds in the supersplit-query round (phase `level_scan`).
    pub scan_seconds: f64,
    /// Seconds in the condition-evaluation round (phase `level_eval`).
    pub eval_seconds: f64,
    /// Seconds in tree update + class-list broadcast (phase
    /// `level_update`).
    pub update_seconds: f64,
}

/// One open leaf during construction.
#[derive(Debug, Clone)]
struct OpenLeaf {
    node_id: u32,
}

/// The tree builder core.
pub struct TreeBuilderCore<'a> {
    pool: &'a dyn SplitterPool,
    topology: &'a Topology,
    params: &'a ForestParams,
    num_features: usize,
}

impl<'a> TreeBuilderCore<'a> {
    pub fn new(
        pool: &'a dyn SplitterPool,
        topology: &'a Topology,
        params: &'a ForestParams,
        num_features: usize,
    ) -> Self {
        Self {
            pool,
            topology,
            params,
            num_features,
        }
    }

    fn sampler(&self) -> FeatureSampler {
        FeatureSampler::new(
            self.params.seed,
            self.num_features,
            self.params.candidates_for(self.num_features),
            self.params.feature_sampling,
        )
    }

    /// Train one tree (Alg. 2). Returns the tree and per-level stats.
    pub fn build_tree(&self, tree_idx: u32) -> Result<(Tree, Vec<LevelStats>)> {
        let _tree_span = crate::span!("build_tree", tree = tree_idx);
        let pool = self.pool;
        let sampler = self.sampler();
        pool.start_tree(tree_idx)?;

        // Step 1-2: root + initial mapping. The builder owns no data, so
        // the root histogram comes from a splitter (labels are
        // replicated; ask splitter 0).
        let root_counts = pool.root_stats(0, tree_idx)?;
        let mut tree = Tree::new_root(root_counts.clone());
        let mut open: Vec<OpenLeaf> = if self.params.child_open(&root_counts, 0) {
            vec![OpenLeaf { node_id: 0 }]
        } else {
            vec![]
        };
        let mut stats = Vec::new();
        let mut depth = 0u32;

        // Step 3-9: loop over depth levels.
        while !open.is_empty() {
            let sw = Stopwatch::start();
            let net_before = pool.net_stats().snapshot();
            let open_before = open.len() as u32;
            let open_weight: u64 = open
                .iter()
                .map(|l| tree.nodes[l.node_id as usize].total_count())
                .sum();

            // Candidate columns per leaf (deterministic from the seed) +
            // the level union m''.
            let leaf_infos: Vec<LeafInfo> = open
                .iter()
                .map(|l| LeafInfo {
                    node_id: l.node_id,
                    totals: tree.nodes[l.node_id as usize].class_counts.clone(),
                })
                .collect();
            let mut union_cols: Vec<usize> = open
                .iter()
                .flat_map(|l| sampler.candidates(tree_idx, depth, l.node_id))
                .collect();
            union_cols.sort_unstable();
            union_cols.dedup();
            let m_double_prime = union_cols.len();

            // Balanced column -> replica assignment for this level.
            let assignment = self.topology.assign_level(&union_cols);

            // Step 3: query the splitters for partial supersplits and
            // merge into the global optimal supersplit.
            let scan_sw = Stopwatch::start();
            let mut best: Vec<Option<SplitCandidate>> = vec![None; open.len()];
            {
                let _span = crate::span!("level_scan", tree = tree_idx, depth = depth);
                for (&s, cols) in &assignment.per_splitter {
                    let q = SupersplitQuery {
                        tree: tree_idx,
                        depth,
                        leaves: leaf_infos.clone(),
                        assigned_columns: cols.clone(),
                    };
                    let partial = pool.find_splits(s, &q)?;
                    anyhow::ensure!(
                        partial.splits.len() == open.len(),
                        "splitter {s} answered {} leaves, expected {}",
                        partial.splits.len(),
                        open.len()
                    );
                    for (leaf, cand) in partial.splits.into_iter().enumerate() {
                        if let Some(c) = cand {
                            best[leaf] =
                                pick_best([best[leaf].take(), Some(c)].into_iter().flatten());
                        }
                    }
                }
            }
            let scan_seconds = scan_sw.seconds();

            // Step 5: ask the owning splitters to evaluate the winning
            // conditions. Group by this level's column owner.
            let eval_sw = Stopwatch::start();
            let eval_span = crate::span!("level_eval", tree = tree_idx, depth = depth);
            let mut eval_requests: std::collections::BTreeMap<usize, EvalQuery> =
                std::collections::BTreeMap::new();
            for (leaf, cand) in best.iter().enumerate() {
                if let Some(c) = cand {
                    let owner = assignment
                        .owner_of(c.condition.feature())
                        .expect("winning feature was assigned this level");
                    eval_requests
                        .entry(owner)
                        .or_insert_with(|| EvalQuery {
                            tree: tree_idx,
                            depth,
                            conditions: Vec::new(),
                        })
                        .conditions
                        .push((leaf as u32 + 1, c.condition.clone()));
                }
            }
            let mut bitmaps: std::collections::BTreeMap<u32, super::messages::Bitmap> =
                std::collections::BTreeMap::new();
            for (&s, q) in &eval_requests {
                let r = pool.eval_conditions(s, q)?;
                for (rank, bm) in r.bitmaps {
                    bitmaps.insert(rank, bm);
                }
            }
            drop(eval_span);
            let eval_seconds = eval_sw.seconds();

            // Steps 4, 6, 8: update the tree structure, decide which
            // children stay open, close split-less leaves.
            let update_sw = Stopwatch::start();
            let update_span = crate::span!("level_update", tree = tree_idx, depth = depth);
            let mut outcomes = Vec::with_capacity(open.len());
            let mut next_open = Vec::new();
            let mut num_splits = 0u32;
            for (leaf, cand) in best.iter().enumerate() {
                let rank = leaf as u32 + 1;
                match cand {
                    None => outcomes.push(LeafOutcome::Closed),
                    Some(c) => {
                        let bm = bitmaps
                            .remove(&rank)
                            .ok_or_else(|| anyhow::anyhow!("missing bitmap for leaf rank {rank}"))?;
                        let node_id = open[leaf].node_id;
                        let (left_id, right_id) = tree.split_node(
                            node_id,
                            c.condition.clone(),
                            c.gain,
                            c.left_counts.clone(),
                            c.right_counts.clone(),
                        );
                        let left_open = self.params.child_open(&c.left_counts, depth + 1);
                        let right_open = self.params.child_open(&c.right_counts, depth + 1);
                        if left_open {
                            next_open.push(OpenLeaf { node_id: left_id });
                        }
                        if right_open {
                            next_open.push(OpenLeaf { node_id: right_id });
                        }
                        num_splits += 1;
                        outcomes.push(LeafOutcome::Split {
                            bitmap: bm,
                            left_open,
                            right_open,
                        });
                    }
                }
            }

            // Step 7: broadcast so every splitter updates its mapping.
            let update = LevelUpdate {
                tree: tree_idx,
                depth,
                outcomes,
            };
            pool.broadcast_level_update(&update)?;
            drop(update_span);
            let update_seconds = update_sw.seconds();

            let net_after = pool.net_stats().snapshot();
            let level_rows = open_weight;
            crate::telemetry::counter("drf_levels_total").inc();
            crate::telemetry::counter("drf_rows_routed_total").add(level_rows);
            stats.push(LevelStats {
                depth,
                seconds: sw.seconds(),
                open_before,
                open_after: next_open.len() as u32,
                num_splits,
                num_closed: open_before - num_splits,
                m_double_prime,
                z_max_load: assignment.max_load,
                net_bytes: net_after.delta_since(&net_before).net_bytes,
                open_weight,
                scan_seconds,
                eval_seconds,
                update_seconds,
            });
            open = next_open;
            depth += 1;
        }

        // Step 10: hand the finished tree to the manager (our caller).
        pool.finish_tree(tree_idx)?;
        crate::telemetry::counter("drf_trees_total").inc();
        Ok((tree, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PruneMode, TopologyParams};
    use crate::coordinator::splitter::{memory_storage_for, SplitterConfig, SplitterCore};
    use crate::coordinator::transport::DirectPool;
    use crate::data::io_stats::IoStats;
    use crate::data::synthetic::{Family, SyntheticSpec};
    use crate::data::Dataset;
    use crate::rng::{Bagger, BaggingMode, FeatureSampling};
    use std::sync::Arc;

    fn setup(
        ds: &Dataset,
        params: &ForestParams,
        num_splitters: usize,
    ) -> (DirectPool, Topology) {
        let topo_params = TopologyParams {
            num_splitters: Some(num_splitters),
            ..Default::default()
        };
        let topology = Topology::new(ds.num_features(), &topo_params);
        let labels = Arc::new(ds.labels().to_vec());
        let cfg = SplitterConfig {
            seed: params.seed,
            bagger: Bagger::new(params.seed, params.bagging),
            feature_sampling: params.feature_sampling,
            num_candidates: params.candidates_for(ds.num_features()),
            score_kind: params.score_kind,
            prune: PruneMode::Never,
            scan_threads: 1,
        };
        let splitters = (0..topology.num_splitters())
            .map(|s| {
                Arc::new(SplitterCore::new(
                    s,
                    ds.schema().clone(),
                    memory_storage_for(ds, &topology.columns_of(s)),
                    labels.clone(),
                    cfg,
                    IoStats::new(),
                ))
            })
            .collect();
        (DirectPool::new(splitters, 0), topology)
    }

    #[test]
    fn builds_a_tree_that_fits_xor() {
        // XOR with 2 informative features, no bagging, all features
        // considered: a depth-2 tree must fit perfectly.
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 400, 2, 5).generate();
        let params = ForestParams {
            num_trees: 1,
            max_depth: 4,
            min_records: 1,
            bagging: BaggingMode::None,
            feature_sampling: FeatureSampling::All,
            seed: 5,
            ..Default::default()
        };
        let (pool, topo) = setup(&ds, &params, 2);
        let builder = TreeBuilderCore::new(&pool, &topo, &params, ds.num_features());
        let (tree, stats) = builder.build_tree(0).unwrap();
        // Training accuracy must be perfect.
        let preds: Vec<u32> = (0..ds.num_rows())
            .map(|i| tree.predict_class(&ds.row(i)))
            .collect();
        assert_eq!(crate::metrics::accuracy(&preds, ds.labels()), 1.0);
        assert!(tree.depth() <= 3);
        assert!(!stats.is_empty());
        assert_eq!(stats[0].open_before, 1);
        assert!(stats.iter().all(|s| s.net_bytes > 0));
        // Per-phase breakdown: phases nest inside the level wall time.
        for s in &stats {
            let phase_sum = s.scan_seconds + s.eval_seconds + s.update_seconds;
            assert!(phase_sum <= s.seconds + 1e-9);
            assert!(s.scan_seconds >= 0.0 && s.eval_seconds >= 0.0 && s.update_seconds >= 0.0);
        }
    }

    #[test]
    fn respects_max_depth() {
        let ds = SyntheticSpec::new(Family::Majority { informative: 5 }, 500, 5, 9).generate();
        let params = ForestParams {
            max_depth: 2,
            bagging: BaggingMode::None,
            feature_sampling: FeatureSampling::All,
            seed: 9,
            ..Default::default()
        };
        let (pool, topo) = setup(&ds, &params, 3);
        let builder = TreeBuilderCore::new(&pool, &topo, &params, ds.num_features());
        let (tree, stats) = builder.build_tree(0).unwrap();
        assert!(tree.depth() <= 2);
        assert!(stats.len() <= 2);
    }

    #[test]
    fn respects_min_records() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 100, 3, 5).generate();
        let params = ForestParams {
            min_records: 60, // root=100 splits once at most, children < 60 close
            max_depth: 10,
            bagging: BaggingMode::None,
            feature_sampling: FeatureSampling::All,
            seed: 5,
            ..Default::default()
        };
        let (pool, topo) = setup(&ds, &params, 3);
        let builder = TreeBuilderCore::new(&pool, &topo, &params, ds.num_features());
        let (tree, _) = builder.build_tree(0).unwrap();
        for node in tree.nodes.iter().filter(|n| !n.is_leaf()) {
            assert!(node.total_count() >= 60, "split a node below min_records");
        }
    }

    #[test]
    fn empty_and_pure_roots_close_immediately() {
        // All labels equal -> pure root -> single-node tree, no queries.
        let mut ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 50, 3, 5).generate();
        ds = Dataset::new(
            ds.schema().clone(),
            ds.columns().to_vec(),
            vec![1u32; 50],
        );
        let params = ForestParams {
            bagging: BaggingMode::None,
            feature_sampling: FeatureSampling::All,
            seed: 5,
            ..Default::default()
        };
        let (pool, topo) = setup(&ds, &params, 2);
        let builder = TreeBuilderCore::new(&pool, &topo, &params, ds.num_features());
        let (tree, stats) = builder.build_tree(0).unwrap();
        assert_eq!(tree.num_nodes(), 1);
        assert!(stats.is_empty());
    }

    #[test]
    fn stats_track_open_weight_and_z() {
        let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 300, 9, 2).generate();
        let params = ForestParams {
            max_depth: 5,
            bagging: BaggingMode::None,
            feature_sampling: FeatureSampling::PerNode,
            seed: 2,
            ..Default::default()
        };
        let (pool, topo) = setup(&ds, &params, 3);
        let builder = TreeBuilderCore::new(&pool, &topo, &params, ds.num_features());
        let (_, stats) = builder.build_tree(0).unwrap();
        assert_eq!(stats[0].open_weight, 300);
        // m' = ceil(sqrt(9)) = 3 and one leaf at depth 0.
        assert_eq!(stats[0].m_double_prime, 3);
        assert!(stats[0].z_max_load >= 1);
        for w in stats.windows(2) {
            assert!(w[1].open_weight <= w[0].open_weight);
        }
    }
}
