//! The tree builder worker — Alg. 2 of the paper, plus the hybrid
//! breadth-first / depth-next growth schedule (arXiv 1910.06853).
//!
//! A tree builder holds the structure of one decision tree in training
//! and coordinates the splitters; it has **no access to the dataset**.
//! Trees grow depth-level by depth-level: one supersplit query round,
//! one condition-evaluation round, and one class-list broadcast per
//! level — never per node.
//!
//! # Depth-next growth
//!
//! Full-dataset passes dominate deep trees: the distributed level
//! rounds scan every owned column once per depth even when the open
//! frontier holds a handful of rows. With a cache budget
//! (`TrainConfig::depth_next_rows` / [`TreeBuilderCore::with_depth_next`],
//! 0 = disabled), any *remote* frontier leaf whose bagged weight fits
//! the budget is **detached** at level start: its in-bag rows are
//! materialized into a compact node-local column set (one `Materialize`
//! RPC per splitter, each shipping its disjoint column subset) and the
//! whole subtree below it grows **resident** — per-level split search
//! runs in RAM over just the subtree's rows, with no further dataset
//! passes and no per-level RPCs for that subtree.
//!
//! Bit-identity with the pure breadth-first schedule is a hard
//! invariant (asserted across every storage backend and the cluster
//! engine in `tests/exactness.rs`): resident subtrees grow in lockstep
//! with the level loop through a merged Remote|Resident frontier walked
//! in breadth-first order, so node ids — and therefore the per-node
//! feature draws of [`FeatureSampler`] — are assigned exactly as in
//! pure BF, and the resident scans reuse the same supersplit scan
//! classes over the same sorted orders, totals, and tie-breaks as the
//! splitters. Detached leaves stay positionally in the level's
//! `SupersplitQuery` (flagged, drawing no candidates) and receive
//! [`LeafOutcome::Detached`] — ≡ `Closed` for every class list — in the
//! level update; once a subtree's last resident leaf closes, a
//! `SubtreeDone` broadcast tells the fleet (observability + recovery
//! probing). When the remote frontier empties entirely, all RPC phases
//! are skipped.

use super::messages::{
    EvalQuery, LeafInfo, LeafOutcome, LevelUpdate, MaterializeQuery, MaterializedColumn,
    SubtreeDone, SupersplitQuery,
};
use super::topology::Topology;
use super::transport::SplitterPool;
use crate::config::ForestParams;
use crate::data::column::SortedEntry;
use crate::metrics::Stopwatch;
use crate::rng::FeatureSampler;
use crate::splits::histogram::Histogram;
use crate::splits::scorer::pick_best;
use crate::splits::{categorical, numerical, SplitCandidate};
use crate::tree::{Condition, Tree};
use crate::Result;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Per-depth-level statistics (feeds the paper's Figure 3 and the
/// complexity benches).
#[derive(Debug, Clone)]
pub struct LevelStats {
    pub depth: u32,
    /// Wall-clock seconds spent on this level.
    pub seconds: f64,
    /// Open leaves entering the level.
    pub open_before: u32,
    /// Open leaves after the level's splits/closes.
    pub open_after: u32,
    /// Leaves that split this level.
    pub num_splits: u32,
    /// Leaves that closed this level.
    pub num_closed: u32,
    /// Distinct candidate columns across all leaves (paper's `m''`).
    pub m_double_prime: usize,
    /// Max columns assigned to one splitter this level (paper's `Z`).
    pub z_max_load: usize,
    /// Network bytes moved during this level.
    pub net_bytes: u64,
    /// Bagged sample weight still in open leaves entering this level.
    pub open_weight: u64,
    /// Seconds in the supersplit-query round (phase `level_scan`).
    pub scan_seconds: f64,
    /// Seconds in the condition-evaluation round (phase `level_eval`).
    pub eval_seconds: f64,
    /// Seconds in tree update + class-list broadcast (phase
    /// `level_update`).
    pub update_seconds: f64,
}

/// Node-local column set for one detached subtree. Shared (via `Arc`)
/// by every open leaf below the detach root; indices are subtree-local
/// row ids, assigned in ascending absolute-row order at materialization
/// time so tie-breaks match the splitters' presorted columns.
struct SubtreeData {
    /// Label per subtree-local row.
    labels: Vec<u32>,
    /// Bagged weight per subtree-local row (all rows are in-bag).
    bags: Vec<u8>,
    /// Every dataset column, indexed by original column id.
    columns: Vec<MaterializedColumn>,
}

/// Where an open leaf's split search runs.
#[derive(Clone)]
enum LeafKind {
    /// Rows live on the splitters; level rounds go over RPC.
    Remote,
    /// Rows are materialized builder-side; splits run in RAM.
    Resident {
        data: Arc<SubtreeData>,
        /// Subtree-local row ids in this leaf, ascending.
        rows: Vec<u32>,
        /// Node id of the detach root (keys the progress tracker).
        root: u32,
    },
}

/// One open leaf during construction.
#[derive(Clone)]
struct OpenLeaf {
    node_id: u32,
    kind: LeafKind,
}

/// Progress accounting for one detached subtree, keyed by its root
/// node id. When `live` hits zero the subtree is finished and a
/// `SubtreeDone` broadcast goes out.
struct SubtreeTracker {
    /// In-bag rows materialized for the subtree.
    rows: u64,
    /// Open resident leaves still growing.
    live: u32,
    /// Tree nodes grown so far (root + 2 per split).
    nodes: u32,
}

/// The tree builder core.
pub struct TreeBuilderCore<'a> {
    pool: &'a dyn SplitterPool,
    topology: &'a Topology,
    params: &'a ForestParams,
    num_features: usize,
    /// Depth-next cache budget in bagged sample weight; 0 disables
    /// hybrid growth (pure breadth-first).
    depth_next_rows: u64,
}

impl<'a> TreeBuilderCore<'a> {
    pub fn new(
        pool: &'a dyn SplitterPool,
        topology: &'a Topology,
        params: &'a ForestParams,
        num_features: usize,
    ) -> Self {
        Self {
            pool,
            topology,
            params,
            num_features,
            depth_next_rows: 0,
        }
    }

    /// Enable depth-next growth: remote frontier leaves whose bagged
    /// weight is at most `rows` are materialized builder-side and
    /// their subtrees grow cache-resident. 0 disables.
    pub fn with_depth_next(mut self, rows: u64) -> Self {
        self.depth_next_rows = rows;
        self
    }

    /// The switch-threshold decision: detach a remote leaf of bagged
    /// weight `weight` into resident growth?
    fn should_detach(&self, weight: u64) -> bool {
        self.depth_next_rows > 0 && weight <= self.depth_next_rows
    }

    fn sampler(&self) -> FeatureSampler {
        FeatureSampler::new(
            self.params.seed,
            self.num_features,
            self.params.candidates_for(self.num_features),
            self.params.feature_sampling,
        )
    }

    /// Train one tree (Alg. 2). Returns the tree and per-level stats.
    pub fn build_tree(&self, tree_idx: u32) -> Result<(Tree, Vec<LevelStats>)> {
        let _tree_span = crate::span!("build_tree", tree = tree_idx);
        let pool = self.pool;
        let sampler = self.sampler();
        pool.start_tree(tree_idx)?;

        // Step 1-2: root + initial mapping. The builder owns no data, so
        // the root histogram comes from a splitter (labels are
        // replicated; ask splitter 0).
        let root_counts = pool.root_stats(0, tree_idx)?;
        let mut tree = Tree::new_root(root_counts.clone());
        let mut open: Vec<OpenLeaf> = if self.params.child_open(&root_counts, 0) {
            vec![OpenLeaf {
                node_id: 0,
                kind: LeafKind::Remote,
            }]
        } else {
            vec![]
        };
        let mut stats = Vec::new();
        let mut depth = 0u32;
        let mut trackers: BTreeMap<u32, SubtreeTracker> = BTreeMap::new();

        // Step 3-9: loop over depth levels.
        while !open.is_empty() {
            let sw = Stopwatch::start();
            let net_before = pool.net_stats().snapshot();
            let open_before = open.len() as u32;
            let open_weight: u64 = open
                .iter()
                .map(|l| tree.nodes[l.node_id as usize].total_count())
                .sum();

            // Depth-next detach phase: remote frontier leaves that fit
            // the cache budget switch to resident growth this level.
            let mut newly_detached = vec![false; open.len()];
            if self.depth_next_rows > 0 {
                let detach: Vec<usize> = open
                    .iter()
                    .enumerate()
                    .filter(|(_, l)| {
                        matches!(l.kind, LeafKind::Remote)
                            && self.should_detach(tree.nodes[l.node_id as usize].total_count())
                    })
                    .map(|(p, _)| p)
                    .collect();
                if !detach.is_empty() {
                    self.materialize_subtrees(
                        tree_idx,
                        depth,
                        &mut open,
                        &detach,
                        &mut trackers,
                    )?;
                    for &p in &detach {
                        newly_detached[p] = true;
                    }
                }
            }

            // The splitters' class-list ranks at level start enumerate
            // the remote-at-level-start frontier in order, which is
            // exactly the Remote leaves plus the newly detached ones
            // (still positionally present, flagged, drawing no
            // candidates).
            let leaf_infos: Vec<LeafInfo> = open
                .iter()
                .enumerate()
                .filter(|(p, l)| matches!(l.kind, LeafKind::Remote) || newly_detached[*p])
                .map(|(p, l)| LeafInfo {
                    node_id: l.node_id,
                    detached: newly_detached[p],
                    totals: tree.nodes[l.node_id as usize].class_counts.clone(),
                })
                .collect();

            // Candidate columns per still-remote leaf (deterministic
            // from the seed) + the level union m''.
            let mut union_cols: Vec<usize> = open
                .iter()
                .enumerate()
                .filter(|(p, l)| matches!(l.kind, LeafKind::Remote) && !newly_detached[*p])
                .flat_map(|(_, l)| sampler.candidates(tree_idx, depth, l.node_id))
                .collect();
            union_cols.sort_unstable();
            union_cols.dedup();
            let m_double_prime = union_cols.len();

            // Balanced column -> replica assignment for this level.
            let assignment = self.topology.assign_level(&union_cols);

            // Step 3: query the splitters for partial supersplits and
            // merge into the global optimal supersplit. With an empty
            // remote frontier no columns are assigned, so the whole RPC
            // round vanishes; resident leaves search in RAM instead.
            let scan_sw = Stopwatch::start();
            let mut best: Vec<Option<SplitCandidate>> = vec![None; leaf_infos.len()];
            {
                let _span = crate::span!("level_scan", tree = tree_idx, depth = depth);
                for (&s, cols) in &assignment.per_splitter {
                    let q = SupersplitQuery {
                        tree: tree_idx,
                        depth,
                        leaves: leaf_infos.clone(),
                        assigned_columns: cols.clone(),
                    };
                    let partial = pool.find_splits(s, &q)?;
                    anyhow::ensure!(
                        partial.splits.len() == leaf_infos.len(),
                        "splitter {s} answered {} leaves, expected {}",
                        partial.splits.len(),
                        leaf_infos.len()
                    );
                    for (leaf, cand) in partial.splits.into_iter().enumerate() {
                        if let Some(c) = cand {
                            best[leaf] =
                                pick_best([best[leaf].take(), Some(c)].into_iter().flatten());
                        }
                    }
                }
            }
            // Resident split search, still inside the scan phase:
            // in-RAM supersplits over each resident leaf's rows.
            let mut resident_best: Vec<Option<SplitCandidate>> = vec![None; open.len()];
            if open
                .iter()
                .any(|l| matches!(l.kind, LeafKind::Resident { .. }))
            {
                let _span = crate::span!("subtree_build", tree = tree_idx, depth = depth);
                for (p, l) in open.iter().enumerate() {
                    if let LeafKind::Resident { data, rows, .. } = &l.kind {
                        resident_best[p] = self.resident_split(
                            tree_idx,
                            depth,
                            l.node_id,
                            data,
                            rows,
                            &tree.nodes[l.node_id as usize].class_counts,
                            &sampler,
                        );
                    }
                }
            }
            let scan_seconds = scan_sw.seconds();

            // Step 5: ask the owning splitters to evaluate the winning
            // conditions. Group by this level's column owner.
            let eval_sw = Stopwatch::start();
            let eval_span = crate::span!("level_eval", tree = tree_idx, depth = depth);
            let mut eval_requests: std::collections::BTreeMap<usize, EvalQuery> =
                std::collections::BTreeMap::new();
            for (leaf, cand) in best.iter().enumerate() {
                if let Some(c) = cand {
                    let owner = assignment
                        .owner_of(c.condition.feature())
                        .expect("winning feature was assigned this level");
                    eval_requests
                        .entry(owner)
                        .or_insert_with(|| EvalQuery {
                            tree: tree_idx,
                            depth,
                            conditions: Vec::new(),
                        })
                        .conditions
                        .push((leaf as u32 + 1, c.condition.clone()));
                }
            }
            let mut bitmaps: std::collections::BTreeMap<u32, super::messages::Bitmap> =
                std::collections::BTreeMap::new();
            for (&s, q) in &eval_requests {
                let r = pool.eval_conditions(s, q)?;
                for (rank, bm) in r.bitmaps {
                    bitmaps.insert(rank, bm);
                }
            }
            drop(eval_span);
            let eval_seconds = eval_sw.seconds();

            // Steps 4, 6, 8: update the tree structure, decide which
            // children stay open, close split-less leaves. The merged
            // Remote|Resident frontier is walked in breadth-first
            // order, so node ids are assigned exactly as in the pure
            // BF schedule.
            let update_sw = Stopwatch::start();
            let update_span = crate::span!("level_update", tree = tree_idx, depth = depth);
            let mut outcomes = Vec::with_capacity(leaf_infos.len());
            let mut next_open = Vec::new();
            let mut num_splits = 0u32;
            let mut info_i = 0usize;
            for (p, leaf) in open.iter_mut().enumerate() {
                if newly_detached[p] {
                    // Freshly detached: ≡ Closed for every splitter's
                    // class list; growth continues residently below.
                    outcomes.push(LeafOutcome::Detached);
                    info_i += 1;
                }
                match &mut leaf.kind {
                    LeafKind::Remote => {
                        let rank = info_i as u32 + 1;
                        let cand = best[info_i].take();
                        info_i += 1;
                        match cand {
                            None => outcomes.push(LeafOutcome::Closed),
                            Some(c) => {
                                let bm = bitmaps.remove(&rank).ok_or_else(|| {
                                    anyhow::anyhow!("missing bitmap for leaf rank {rank}")
                                })?;
                                let (left_id, right_id) = tree.split_node(
                                    leaf.node_id,
                                    c.condition.clone(),
                                    c.gain,
                                    c.left_counts.clone(),
                                    c.right_counts.clone(),
                                );
                                let left_open = self.params.child_open(&c.left_counts, depth + 1);
                                let right_open = self.params.child_open(&c.right_counts, depth + 1);
                                if left_open {
                                    next_open.push(OpenLeaf {
                                        node_id: left_id,
                                        kind: LeafKind::Remote,
                                    });
                                }
                                if right_open {
                                    next_open.push(OpenLeaf {
                                        node_id: right_id,
                                        kind: LeafKind::Remote,
                                    });
                                }
                                num_splits += 1;
                                outcomes.push(LeafOutcome::Split {
                                    bitmap: bm,
                                    left_open,
                                    right_open,
                                });
                            }
                        }
                    }
                    LeafKind::Resident { data, rows, root } => {
                        let tracker = trackers
                            .get_mut(root)
                            .expect("resident leaf without a subtree tracker");
                        match resident_best[p].take() {
                            None => tracker.live -= 1,
                            Some(c) => {
                                let (left_id, right_id) = tree.split_node(
                                    leaf.node_id,
                                    c.condition.clone(),
                                    c.gain,
                                    c.left_counts.clone(),
                                    c.right_counts.clone(),
                                );
                                let left_open = self.params.child_open(&c.left_counts, depth + 1);
                                let right_open = self.params.child_open(&c.right_counts, depth + 1);
                                let (left_rows, right_rows) =
                                    partition_rows(data, rows, &c.condition);
                                if left_open {
                                    next_open.push(OpenLeaf {
                                        node_id: left_id,
                                        kind: LeafKind::Resident {
                                            data: data.clone(),
                                            rows: left_rows,
                                            root: *root,
                                        },
                                    });
                                }
                                if right_open {
                                    next_open.push(OpenLeaf {
                                        node_id: right_id,
                                        kind: LeafKind::Resident {
                                            data: data.clone(),
                                            rows: right_rows,
                                            root: *root,
                                        },
                                    });
                                }
                                num_splits += 1;
                                tracker.live =
                                    tracker.live - 1 + left_open as u32 + right_open as u32;
                                tracker.nodes += 2;
                            }
                        }
                    }
                }
            }

            // Step 7: broadcast so every splitter updates its mapping.
            // Skipped entirely once the remote frontier is empty.
            if !leaf_infos.is_empty() {
                let update = LevelUpdate {
                    tree: tree_idx,
                    depth,
                    outcomes,
                };
                pool.broadcast_level_update(&update)?;
            }
            // Announce finished subtrees to the fleet.
            let done: Vec<u32> = trackers
                .iter()
                .filter(|(_, t)| t.live == 0)
                .map(|(&root, _)| root)
                .collect();
            for root in done {
                let t = trackers.remove(&root).expect("tracker vanished");
                pool.broadcast_subtree_done(&SubtreeDone {
                    tree: tree_idx,
                    root,
                    rows: t.rows,
                    nodes: t.nodes,
                })?;
            }
            drop(update_span);
            let update_seconds = update_sw.seconds();

            let net_after = pool.net_stats().snapshot();
            let level_rows = open_weight;
            crate::telemetry::counter("drf_levels_total").inc();
            crate::telemetry::counter("drf_rows_routed_total").add(level_rows);
            stats.push(LevelStats {
                depth,
                seconds: sw.seconds(),
                open_before,
                open_after: next_open.len() as u32,
                num_splits,
                num_closed: open_before - num_splits,
                m_double_prime,
                z_max_load: assignment.max_load,
                net_bytes: net_after.delta_since(&net_before).net_bytes,
                open_weight,
                scan_seconds,
                eval_seconds,
                update_seconds,
            });
            open = next_open;
            depth += 1;
        }

        // Step 10: hand the finished tree to the manager (our caller).
        pool.finish_tree(tree_idx)?;
        crate::telemetry::counter("drf_trees_total").inc();
        Ok((tree, stats))
    }

    /// Detach the frontier leaves at positions `detach` into resident
    /// growth: fetch their in-bag rows as node-local column sets (one
    /// `Materialize` RPC per splitter, each shipping its disjoint
    /// column share) and rewrite the leaves' kind in place.
    fn materialize_subtrees(
        &self,
        tree_idx: u32,
        depth: u32,
        open: &mut [OpenLeaf],
        detach: &[usize],
        trackers: &mut BTreeMap<u32, SubtreeTracker>,
    ) -> Result<()> {
        let _span = crate::span!(
            "subtree_materialize",
            tree = tree_idx,
            depth = depth,
            leaves = detach.len()
        );
        // Splitter-side class-list ranks at level start enumerate the
        // remote frontier in order.
        let mut rank = 0u32;
        let mut remote_rank = vec![0u32; open.len()];
        for (p, l) in open.iter().enumerate() {
            if matches!(l.kind, LeafKind::Remote) {
                rank += 1;
                remote_rank[p] = rank;
            }
        }
        let ranks: Vec<u32> = detach.iter().map(|&p| remote_rank[p]).collect();

        // Every column ships — resident growth may draw any candidate
        // at deeper levels. Routed disjointly across the replicas;
        // labels + bags come from the lowest-id assigned splitter.
        let all_cols: Vec<usize> = (0..self.num_features).collect();
        let assignment = self.topology.assign_level(&all_cols);
        let meta_splitter = *assignment
            .per_splitter
            .keys()
            .next()
            .ok_or_else(|| anyhow::anyhow!("no splitters to materialize from"))?;

        let mut rows_per_leaf: Vec<Option<u64>> = vec![None; detach.len()];
        let mut labels: Vec<Vec<u32>> = vec![Vec::new(); detach.len()];
        let mut bags: Vec<Vec<u8>> = vec![Vec::new(); detach.len()];
        let mut columns: Vec<Vec<Option<MaterializedColumn>>> = detach
            .iter()
            .map(|_| (0..self.num_features).map(|_| None).collect())
            .collect();
        for (&s, cols) in &assignment.per_splitter {
            let q = MaterializeQuery {
                tree: tree_idx,
                depth,
                ranks: ranks.clone(),
                columns: cols.clone(),
                want_meta: s == meta_splitter,
            };
            let m = self.pool.materialize(s, &q)?;
            anyhow::ensure!(
                m.leaves.len() == detach.len(),
                "splitter {s} materialized {} leaves, expected {}",
                m.leaves.len(),
                detach.len()
            );
            for (k, leaf) in m.leaves.into_iter().enumerate() {
                // Replicas must agree on the in-bag row set.
                match rows_per_leaf[k] {
                    None => rows_per_leaf[k] = Some(leaf.rows),
                    Some(prev) => anyhow::ensure!(
                        prev == leaf.rows,
                        "splitter {s} disagrees on leaf rows: {} vs {prev}",
                        leaf.rows
                    ),
                }
                anyhow::ensure!(
                    leaf.columns.len() == cols.len(),
                    "splitter {s} sent {} columns, expected {}",
                    leaf.columns.len(),
                    cols.len()
                );
                for (&j, col) in cols.iter().zip(leaf.columns) {
                    columns[k][j] = Some(col);
                }
                if q.want_meta {
                    labels[k] = leaf.labels;
                    bags[k] = leaf.bags;
                }
            }
        }

        for (k, &p) in detach.iter().enumerate() {
            let node_id = open[p].node_id;
            let n = rows_per_leaf[k]
                .ok_or_else(|| anyhow::anyhow!("leaf {node_id} was never materialized"))?;
            anyhow::ensure!(
                labels[k].len() as u64 == n && bags[k].len() as u64 == n,
                "leaf {node_id}: meta length mismatch ({} labels, {} bags, {n} rows)",
                labels[k].len(),
                bags[k].len()
            );
            let cols: Vec<MaterializedColumn> = std::mem::take(&mut columns[k])
                .into_iter()
                .enumerate()
                .map(|(j, c)| c.ok_or_else(|| anyhow::anyhow!("column {j} was never assigned")))
                .collect::<Result<_>>()?;
            let data = Arc::new(SubtreeData {
                labels: std::mem::take(&mut labels[k]),
                bags: std::mem::take(&mut bags[k]),
                columns: cols,
            });
            trackers.insert(
                node_id,
                SubtreeTracker {
                    rows: n,
                    live: 1,
                    nodes: 1,
                },
            );
            crate::telemetry::counter("drf_subtrees_total").inc();
            crate::telemetry::counter("drf_subtree_rows").add(n);
            open[p].kind = LeafKind::Resident {
                data,
                rows: (0..n as u32).collect(),
                root: node_id,
            };
        }
        Ok(())
    }

    /// Exact split search for one resident leaf: the same supersplit
    /// scans the splitters run, over the subtree-local column set.
    /// Single-leaf totals, identical sort order and tie-breaks, so the
    /// winner is bit-identical to what the distributed round would
    /// have produced.
    #[allow(clippy::too_many_arguments)]
    fn resident_split(
        &self,
        tree_idx: u32,
        depth: u32,
        node_id: u32,
        data: &SubtreeData,
        rows: &[u32],
        class_counts: &[u64],
        sampler: &FeatureSampler,
    ) -> Option<SplitCandidate> {
        let num_classes = class_counts.len() as u32;
        let leaf_totals = [Histogram::from_counts(class_counts.to_vec())];
        let kind = self.params.score_kind;
        let mut best: Option<SplitCandidate> = None;
        for j in sampler.candidates(tree_idx, depth, node_id) {
            let cand = match &data.columns[j] {
                MaterializedColumn::Num(values) => {
                    let mut entries: Vec<SortedEntry> = rows
                        .iter()
                        .map(|&i| SortedEntry {
                            value: values[i as usize],
                            sample: i,
                        })
                        .collect();
                    // Same order as the splitters' presorted columns:
                    // by value, ties by row id (local ids are assigned
                    // in ascending absolute-row order).
                    entries.sort_unstable_by(|a, b| {
                        a.value
                            .partial_cmp(&b.value)
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(a.sample.cmp(&b.sample))
                    });
                    let mut scan = numerical::NumericalSupersplitScan::new(
                        j,
                        &data.labels,
                        num_classes,
                        &leaf_totals,
                        kind,
                        |i| (1, data.bags[i as usize] as u32),
                    );
                    scan.push(&entries);
                    scan.finish().pop().flatten()
                }
                MaterializedColumn::Cat { arity, values } => {
                    let vals: Vec<u32> = rows.iter().map(|&i| values[i as usize]).collect();
                    let leaf_labels: Vec<u32> =
                        rows.iter().map(|&i| data.labels[i as usize]).collect();
                    let leaf_bags: Vec<u8> = rows.iter().map(|&i| data.bags[i as usize]).collect();
                    let mut scan = categorical::CategoricalSupersplitScan::new(
                        j,
                        *arity,
                        &leaf_labels,
                        num_classes,
                        &leaf_totals,
                        kind,
                        |i| (1, leaf_bags[i as usize] as u32),
                    );
                    scan.push(0, &vals);
                    scan.finish().pop().flatten()
                }
            };
            best = pick_best([best.take(), cand].into_iter().flatten());
        }
        best
    }
}

/// Partition a resident leaf's rows by the winning condition,
/// preserving ascending order. Condition true -> left, mirroring the
/// splitters' bitmap semantics.
fn partition_rows(data: &SubtreeData, rows: &[u32], cond: &Condition) -> (Vec<u32>, Vec<u32>) {
    let mut left = Vec::new();
    let mut right = Vec::new();
    for &i in rows {
        let goes_left = match cond {
            Condition::NumLe { feature, threshold } => match &data.columns[*feature] {
                MaterializedColumn::Num(values) => values[i as usize] <= *threshold,
                MaterializedColumn::Cat { .. } => false,
            },
            Condition::CatIn { feature, set } => match &data.columns[*feature] {
                MaterializedColumn::Cat { values, .. } => set.contains(values[i as usize]),
                MaterializedColumn::Num(_) => false,
            },
        };
        if goes_left {
            left.push(i);
        } else {
            right.push(i);
        }
    }
    (left, right)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PruneMode, SplitSearch, TopologyParams};
    use crate::coordinator::splitter::{memory_storage_for, SplitterConfig, SplitterCore};
    use crate::coordinator::transport::DirectPool;
    use crate::data::io_stats::IoStats;
    use crate::data::synthetic::{Family, SyntheticSpec};
    use crate::data::Dataset;
    use crate::rng::{Bagger, BaggingMode, FeatureSampling};
    use std::sync::Arc;

    fn setup(
        ds: &Dataset,
        params: &ForestParams,
        num_splitters: usize,
    ) -> (DirectPool, Topology) {
        let topo_params = TopologyParams {
            num_splitters: Some(num_splitters),
            ..Default::default()
        };
        let topology = Topology::new(ds.num_features(), &topo_params);
        let labels = Arc::new(ds.labels().to_vec());
        let cfg = SplitterConfig {
            seed: params.seed,
            bagger: Bagger::new(params.seed, params.bagging),
            feature_sampling: params.feature_sampling,
            num_candidates: params.candidates_for(ds.num_features()),
            score_kind: params.score_kind,
            prune: PruneMode::Never,
            scan_threads: 1,
            split_search: SplitSearch::Exact,
        };
        let splitters = (0..topology.num_splitters())
            .map(|s| {
                Arc::new(SplitterCore::new(
                    s,
                    ds.schema().clone(),
                    memory_storage_for(ds, &topology.columns_of(s)),
                    labels.clone(),
                    cfg,
                    IoStats::new(),
                ))
            })
            .collect();
        (DirectPool::new(splitters, 0), topology)
    }

    #[test]
    fn builds_a_tree_that_fits_xor() {
        // XOR with 2 informative features, no bagging, all features
        // considered: a depth-2 tree must fit perfectly.
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 400, 2, 5).generate();
        let params = ForestParams {
            num_trees: 1,
            max_depth: 4,
            min_records: 1,
            bagging: BaggingMode::None,
            feature_sampling: FeatureSampling::All,
            seed: 5,
            ..Default::default()
        };
        let (pool, topo) = setup(&ds, &params, 2);
        let builder = TreeBuilderCore::new(&pool, &topo, &params, ds.num_features());
        let (tree, stats) = builder.build_tree(0).unwrap();
        // Training accuracy must be perfect.
        let preds: Vec<u32> = (0..ds.num_rows())
            .map(|i| tree.predict_class(&ds.row(i)))
            .collect();
        assert_eq!(crate::metrics::accuracy(&preds, ds.labels()), 1.0);
        assert!(tree.depth() <= 3);
        assert!(!stats.is_empty());
        assert_eq!(stats[0].open_before, 1);
        assert!(stats.iter().all(|s| s.net_bytes > 0));
        // Per-phase breakdown: phases nest inside the level wall time.
        for s in &stats {
            let phase_sum = s.scan_seconds + s.eval_seconds + s.update_seconds;
            assert!(phase_sum <= s.seconds + 1e-9);
            assert!(s.scan_seconds >= 0.0 && s.eval_seconds >= 0.0 && s.update_seconds >= 0.0);
        }
    }

    #[test]
    fn respects_max_depth() {
        let ds = SyntheticSpec::new(Family::Majority { informative: 5 }, 500, 5, 9).generate();
        let params = ForestParams {
            max_depth: 2,
            bagging: BaggingMode::None,
            feature_sampling: FeatureSampling::All,
            seed: 9,
            ..Default::default()
        };
        let (pool, topo) = setup(&ds, &params, 3);
        let builder = TreeBuilderCore::new(&pool, &topo, &params, ds.num_features());
        let (tree, stats) = builder.build_tree(0).unwrap();
        assert!(tree.depth() <= 2);
        assert!(stats.len() <= 2);
    }

    #[test]
    fn respects_min_records() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 100, 3, 5).generate();
        let params = ForestParams {
            min_records: 60, // root=100 splits once at most, children < 60 close
            max_depth: 10,
            bagging: BaggingMode::None,
            feature_sampling: FeatureSampling::All,
            seed: 5,
            ..Default::default()
        };
        let (pool, topo) = setup(&ds, &params, 3);
        let builder = TreeBuilderCore::new(&pool, &topo, &params, ds.num_features());
        let (tree, _) = builder.build_tree(0).unwrap();
        for node in tree.nodes.iter().filter(|n| !n.is_leaf()) {
            assert!(node.total_count() >= 60, "split a node below min_records");
        }
    }

    #[test]
    fn empty_and_pure_roots_close_immediately() {
        // All labels equal -> pure root -> single-node tree, no queries.
        let mut ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 50, 3, 5).generate();
        ds = Dataset::new(
            ds.schema().clone(),
            ds.columns().to_vec(),
            vec![1u32; 50],
        );
        let params = ForestParams {
            bagging: BaggingMode::None,
            feature_sampling: FeatureSampling::All,
            seed: 5,
            ..Default::default()
        };
        let (pool, topo) = setup(&ds, &params, 2);
        let builder = TreeBuilderCore::new(&pool, &topo, &params, ds.num_features());
        let (tree, stats) = builder.build_tree(0).unwrap();
        assert_eq!(tree.num_nodes(), 1);
        assert!(stats.is_empty());
    }

    #[test]
    fn switch_threshold_decision() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 100, 3, 5).generate();
        let params = ForestParams::default();
        let (pool, topo) = setup(&ds, &params, 2);
        // Disabled at 0: nothing detaches, whatever the weight.
        let builder = TreeBuilderCore::new(&pool, &topo, &params, ds.num_features());
        assert!(!builder.should_detach(0));
        assert!(!builder.should_detach(1));
        assert!(!builder.should_detach(u64::MAX));
        // Boundary: the budget is inclusive.
        let builder = builder.with_depth_next(1000);
        assert!(builder.should_detach(999));
        assert!(builder.should_detach(1000));
        assert!(!builder.should_detach(1001));
        assert!(builder.should_detach(1));
    }

    #[test]
    fn depth_next_is_bit_identical_to_breadth_first() {
        // The tentpole invariant: hybrid growth must produce the exact
        // same tree as the pure level-by-level schedule, across detach
        // budgets that switch at the root, mid-tree, and never.
        let ds = SyntheticSpec::new(Family::LinearCont { informative: 3 }, 400, 6, 21).generate();
        let params = ForestParams {
            num_trees: 1,
            max_depth: 8,
            min_records: 2,
            bagging: BaggingMode::Poisson,
            feature_sampling: FeatureSampling::PerNode,
            seed: 77,
            ..Default::default()
        };
        let (pool, topo) = setup(&ds, &params, 3);
        let bf = TreeBuilderCore::new(&pool, &topo, &params, ds.num_features())
            .build_tree(0)
            .unwrap()
            .0;
        for budget in [1, 50, 200, 100_000] {
            let (pool, topo) = setup(&ds, &params, 3);
            let hybrid = TreeBuilderCore::new(&pool, &topo, &params, ds.num_features())
                .with_depth_next(budget)
                .build_tree(0)
                .unwrap()
                .0;
            assert_eq!(bf, hybrid, "budget {budget} changed the tree");
        }
    }

    #[test]
    fn depth_next_skips_rpc_rounds_once_resident() {
        // With a budget larger than the dataset the root detaches at
        // depth 0; every later level must move zero network bytes
        // until the final SubtreeDone broadcast.
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 200, 4, 3).generate();
        let params = ForestParams {
            num_trees: 1,
            max_depth: 6,
            min_records: 1,
            bagging: BaggingMode::None,
            feature_sampling: FeatureSampling::All,
            seed: 11,
            ..Default::default()
        };
        let (pool, topo) = setup(&ds, &params, 2);
        let builder =
            TreeBuilderCore::new(&pool, &topo, &params, ds.num_features()).with_depth_next(1 << 20);
        let (tree, stats) = builder.build_tree(0).unwrap();
        assert!(tree.depth() >= 2, "tree should actually grow");
        // Depth 0 pays for materialization + the Detached update; the
        // in-between levels are RPC-free (the last level carries the
        // SubtreeDone broadcast).
        assert!(stats[0].net_bytes > 0);
        for s in &stats[1..stats.len() - 1] {
            assert_eq!(
                s.net_bytes, 0,
                "depth {} moved bytes with a fully resident frontier",
                s.depth
            );
        }
    }

    #[test]
    fn stats_track_open_weight_and_z() {
        let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 300, 9, 2).generate();
        let params = ForestParams {
            max_depth: 5,
            bagging: BaggingMode::None,
            feature_sampling: FeatureSampling::PerNode,
            seed: 2,
            ..Default::default()
        };
        let (pool, topo) = setup(&ds, &params, 3);
        let builder = TreeBuilderCore::new(&pool, &topo, &params, ds.num_features());
        let (_, stats) = builder.build_tree(0).unwrap();
        assert_eq!(stats[0].open_weight, 300);
        // m' = ceil(sqrt(9)) = 3 and one leaf at depth 0.
        assert_eq!(stats[0].m_double_prime, 3);
        assert!(stats[0].z_max_load >= 1);
        for w in stats.windows(2) {
            assert!(w[1].open_weight <= w[0].open_weight);
        }
    }
}
