//! Single-machine SPRINT (Shafer et al. 1996) with full cost accounting
//! — Table 1's other main comparator.
//!
//! SPRINT's signature data structure is the **per-node attribute list**:
//! every feature's `(value, label, rid)` list is physically partitioned
//! when a node splits, so records in closed leaves vanish from future
//! scans (the "pruning" DRF §3 discusses). The price is the rewrite:
//! every split rewrites *all* the node's attribute lists (`K·n·D̄`
//! writes) and builds a rid→side hash map to route the non-winning
//! features' lists.
//!
//! Decision primitives are shared with DRF, so SPRINT also produces
//! identical trees; only the measured costs differ.

use crate::config::ForestParams;
use crate::data::column::{Column, SortedEntry};
use crate::data::io_stats::IoStats;
use crate::data::Dataset;
use crate::rng::{Bagger, FeatureSampler};
use crate::splits::histogram::Histogram;
use crate::splits::scorer::pick_best;
use crate::splits::{categorical, numerical, SplitCandidate};
use crate::tree::{Condition, Tree};
use std::collections::HashMap;

/// One node's physical data: per-feature attribute lists.
struct NodeData {
    node_id: u32,
    /// Per feature: sorted entries for numerical columns (value order),
    /// or (rid-order) raw values for categorical columns.
    numerical: HashMap<usize, Vec<SortedEntry>>,
    categorical: HashMap<usize, Vec<(u32, u32)>>, // (rid, value)
}

/// Single-machine SPRINT trainer with I/O accounting.
pub struct SprintTrainer<'a> {
    ds: &'a Dataset,
    params: &'a ForestParams,
    bagger: Bagger,
    sampler: FeatureSampler,
    stats: IoStats,
    /// Peak bytes held in rid hash maps (the structure Table 1 charges
    /// SPRINT's memory for).
    peak_hash_bytes: std::cell::Cell<u64>,
}

impl<'a> SprintTrainer<'a> {
    pub fn new(ds: &'a Dataset, params: &'a ForestParams, stats: IoStats) -> Self {
        Self {
            ds,
            params,
            bagger: Bagger::new(params.seed, params.bagging),
            sampler: FeatureSampler::new(
                params.seed,
                ds.num_features(),
                params.candidates_for(ds.num_features()),
                params.feature_sampling,
            ),
            stats,
            peak_hash_bytes: std::cell::Cell::new(0),
        }
    }

    pub fn stats(&self) -> &IoStats {
        &self.stats
    }

    pub fn peak_hash_bytes(&self) -> u64 {
        self.peak_hash_bytes.get()
    }

    /// Train one tree, node-at-a-time within each depth level.
    pub fn train_tree(&self, tree_idx: u32) -> Tree {
        let ds = self.ds;
        let n = ds.num_rows();
        let labels = ds.labels();
        let weights: Vec<u32> = (0..n)
            .map(|i| self.bagger.weight(tree_idx, i as u64))
            .collect();
        let in_bag: Vec<u32> = (0..n as u32).filter(|&i| weights[i as usize] > 0).collect();

        // Build the root's attribute lists (the initial partition +
        // presort; charged as PS).
        let mut root = NodeData {
            node_id: 0,
            numerical: HashMap::new(),
            categorical: HashMap::new(),
        };
        for j in 0..ds.num_features() {
            match ds.column(j) {
                Column::Numerical(vals) => {
                    let mut entries: Vec<SortedEntry> = in_bag
                        .iter()
                        .map(|&i| SortedEntry {
                            value: vals[i as usize],
                            sample: i,
                        })
                        .collect();
                    entries.sort_by(|a, b| {
                        a.value
                            .partial_cmp(&b.value)
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(a.sample.cmp(&b.sample))
                    });
                    self.stats.add_disk_read(n as u64 * 4);
                    self.stats.add_read_pass();
                    self.stats.add_disk_write(entries.len() as u64 * 12);
                    self.stats.add_write_pass();
                    root.numerical.insert(j, entries);
                }
                Column::Categorical { values, .. } => {
                    let list: Vec<(u32, u32)> =
                        in_bag.iter().map(|&i| (i, values[i as usize])).collect();
                    self.stats.add_disk_read(n as u64 * 4);
                    self.stats.add_read_pass();
                    self.stats.add_disk_write(list.len() as u64 * 12);
                    self.stats.add_write_pass();
                    root.categorical.insert(j, list);
                }
            }
        }

        let mut root_hist = Histogram::new(ds.num_classes());
        for &i in &in_bag {
            root_hist.add(labels[i as usize], weights[i as usize]);
        }
        let root_counts = root_hist.into_counts();
        let mut tree = Tree::new_root(root_counts.clone());
        let mut open: Vec<NodeData> = if self.params.child_open(&root_counts, 0) {
            vec![root]
        } else {
            vec![]
        };
        let mut depth = 0u32;

        while !open.is_empty() {
            let mut next_open = Vec::new();
            for node in std::mem::take(&mut open) {
                let node_id = node.node_id;
                let totals =
                    [Histogram::from_counts(tree.nodes[node_id as usize].class_counts.clone())];
                let candidates = self.sampler.candidates(tree_idx, depth, node_id);
                let mut best: Option<SplitCandidate> = None;
                for &j in &candidates {
                    let cand = if let Some(entries) = node.numerical.get(&j) {
                        // Scan this node's (already pruned-to-node) list.
                        self.stats.add_disk_read(entries.len() as u64 * 12);
                        self.stats.add_read_pass();
                        numerical::best_numerical_supersplit(
                            j,
                            entries,
                            labels,
                            ds.num_classes(),
                            &totals,
                            self.params.score_kind,
                            |_| 1,
                            |_| true,
                            |i| weights[i as usize],
                        )
                        .pop()
                        .flatten()
                    } else if let Some(list) = node.categorical.get(&j) {
                        self.stats.add_disk_read(list.len() as u64 * 12);
                        self.stats.add_read_pass();
                        let values: Vec<u32> = list.iter().map(|&(_, v)| v).collect();
                        let sub_labels: Vec<u32> =
                            list.iter().map(|&(i, _)| labels[i as usize]).collect();
                        let rids: Vec<u32> = list.iter().map(|&(i, _)| i).collect();
                        let w = &weights;
                        let arity = ds.column(j).arity().unwrap();
                        categorical::best_categorical_supersplit(
                            j,
                            &values,
                            arity,
                            &sub_labels,
                            ds.num_classes(),
                            &totals,
                            self.params.score_kind,
                            |_| 1,
                            |_| true,
                            move |k| w[rids[k as usize] as usize],
                        )
                        .pop()
                        .flatten()
                    } else {
                        None
                    };
                    if let Some(c) = cand {
                        best = pick_best([best.take(), Some(c)].into_iter().flatten());
                    }
                }

                let Some(c) = best else { continue };
                let (l, r) = tree.split_node(
                    node_id,
                    c.condition.clone(),
                    c.gain,
                    c.left_counts.clone(),
                    c.right_counts.clone(),
                );
                let left_open = self.params.child_open(&c.left_counts, depth + 1);
                let right_open = self.params.child_open(&c.right_counts, depth + 1);

                // Build the rid -> goes_left hash map from the winning
                // feature's list (SPRINT's probe structure; in the
                // distributed version this is what gets broadcast).
                let mut side: HashMap<u32, bool> = HashMap::new();
                match &c.condition {
                    Condition::NumLe { feature, threshold } => {
                        for e in node.numerical.get(feature).unwrap() {
                            side.insert(e.sample, e.value <= *threshold);
                        }
                    }
                    Condition::CatIn { feature, set } => {
                        for &(rid, v) in node.categorical.get(feature).unwrap() {
                            side.insert(rid, set.contains(v));
                        }
                    }
                }
                let hash_bytes = side.len() as u64 * 8;
                self.stats.add_net(hash_bytes); // broadcast in distributed SPRINT
                self.peak_hash_bytes
                    .set(self.peak_hash_bytes.get().max(hash_bytes));

                // Partition every attribute list of the node (the
                // expensive rewrite: K passes of the node's records).
                let mut left = NodeData {
                    node_id: l,
                    numerical: HashMap::new(),
                    categorical: HashMap::new(),
                };
                let mut right = NodeData {
                    node_id: r,
                    numerical: HashMap::new(),
                    categorical: HashMap::new(),
                };
                for (j, entries) in node.numerical {
                    self.stats.add_disk_read(entries.len() as u64 * 12);
                    self.stats.add_read_pass();
                    let (mut le, mut re) = (Vec::new(), Vec::new());
                    for e in entries {
                        if side[&e.sample] {
                            le.push(e);
                        } else {
                            re.push(e);
                        }
                    }
                    self.stats.add_disk_write((le.len() + re.len()) as u64 * 12);
                    self.stats.add_write_pass();
                    if left_open {
                        left.numerical.insert(j, le);
                    }
                    if right_open {
                        right.numerical.insert(j, re);
                    }
                }
                for (j, list) in node.categorical {
                    self.stats.add_disk_read(list.len() as u64 * 12);
                    self.stats.add_read_pass();
                    let (mut ll, mut rl) = (Vec::new(), Vec::new());
                    for e in list {
                        if side[&e.0] {
                            ll.push(e);
                        } else {
                            rl.push(e);
                        }
                    }
                    self.stats.add_disk_write((ll.len() + rl.len()) as u64 * 12);
                    self.stats.add_write_pass();
                    if left_open {
                        left.categorical.insert(j, ll);
                    }
                    if right_open {
                        right.categorical.insert(j, rl);
                    }
                }
                if left_open {
                    next_open.push(left);
                }
                if right_open {
                    next_open.push(right);
                }
            }
            open = next_open;
            depth += 1;
        }
        tree
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::classic::ClassicTrainer;
    use crate::baselines::sliq::SliqTrainer;
    use crate::data::synthetic::{Family, SyntheticSpec};
    use crate::rng::BaggingMode;

    #[test]
    fn sprint_matches_classic_and_sliq() {
        let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 300, 6, 4).generate();
        let params = ForestParams {
            num_trees: 1,
            max_depth: 5,
            bagging: BaggingMode::Poisson,
            seed: 77,
            ..Default::default()
        };
        let sprint_tree = SprintTrainer::new(&ds, &params, IoStats::new()).train_tree(0);
        let classic_tree = ClassicTrainer::new(&ds, &params).train_tree(0);
        let sliq_tree = SliqTrainer::new(&ds, &params, IoStats::new()).train_tree(0);
        assert_eq!(sprint_tree, classic_tree, "SPRINT must be exact");
        assert_eq!(sprint_tree, sliq_tree);
    }

    #[test]
    fn sprint_writes_scale_with_splits() {
        let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 500, 4, 4).generate();
        let params = ForestParams {
            num_trees: 1,
            max_depth: 6,
            bagging: BaggingMode::None,
            feature_sampling: crate::rng::FeatureSampling::All,
            seed: 5,
            ..Default::default()
        };
        let stats = IoStats::new();
        let trainer = SprintTrainer::new(&ds, &params, stats.clone());
        let tree = trainer.train_tree(0);
        let internal = tree.nodes.iter().filter(|n| !n.is_leaf()).count() as u64;
        assert!(internal >= 2);
        // Every split rewrites all 4 attribute lists of the node: write
        // passes >= PS(4) + 4 * splits.
        assert!(
            stats.disk_write_passes() >= 4 + 4 * internal,
            "write passes {} for {} splits",
            stats.disk_write_passes(),
            internal
        );
        assert!(trainer.peak_hash_bytes() > 0);
    }

    #[test]
    fn sprint_prunes_closed_leaf_records() {
        // With min_records high, leaves close early; SPRINT's later
        // levels scan fewer records than n per list. We check that the
        // read bytes for a deep tree are far below the no-pruning bound.
        let ds = SyntheticSpec::new(Family::LinearCont { informative: 2 }, 2000, 2, 4).generate();
        let params = ForestParams {
            num_trees: 1,
            max_depth: 10,
            min_records: 500,
            bagging: BaggingMode::None,
            feature_sampling: crate::rng::FeatureSampling::All,
            seed: 5,
            ..Default::default()
        };
        let stats = IoStats::new();
        let tree = SprintTrainer::new(&ds, &params, stats.clone()).train_tree(0);
        let d = tree.depth() as u64;
        assert!(d >= 2);
        // No-pruning bound would be >= m * n * 12 * depth for the scans
        // alone; pruning + early closes must keep us well under it.
        let no_prune_scan_bound = 2 * 2000 * 12 * d;
        assert!(
            stats.disk_read_bytes() < no_prune_scan_bound * 2,
            "reads {} vs bound {}",
            stats.disk_read_bytes(),
            no_prune_scan_bound
        );
    }
}
