//! Single-machine SLIQ (Mehta et al. 1996) with full cost accounting —
//! one of Table 1's comparators.
//!
//! SLIQ trains depth-level-by-depth-level from presorted attribute
//! lists, like DRF, but with the data-structure choices Table 1
//! contrasts:
//!
//! * the **class list** stores, per sample, the leaf id *and the label*
//!   — `n × ([value] + [leaf index])` memory vs DRF's
//!   `n·⌈log2(ℓ+1)⌉` bits;
//! * attribute lists store `(value, record index)` and are re-read in
//!   full every level for every candidate feature (`(m''+1)·n·D` reads,
//!   no column distribution);
//! * class-list updates are in-place random-access writes.
//!
//! Decision primitives are shared with DRF, so SLIQ produces identical
//! trees — the cost counters are what differ (asserted in the Table 1
//! bench).

use crate::config::ForestParams;
use crate::data::column::Column;
use crate::data::io_stats::IoStats;
use crate::data::Dataset;
use crate::rng::{Bagger, FeatureSampler};
use crate::splits::histogram::Histogram;
use crate::splits::scorer::pick_best;
use crate::splits::{categorical, numerical, SplitCandidate};
use crate::tree::{Condition, Tree};

/// SLIQ class-list entry: label + current leaf (the fat layout the
/// paper's Table 1 charges SLIQ for).
#[derive(Debug, Clone, Copy)]
struct ClassEntry {
    label: u32,
    /// 0 = closed, 1.. = open leaf rank.
    leaf: u32,
}

/// Single-machine SLIQ trainer with I/O accounting.
pub struct SliqTrainer<'a> {
    ds: &'a Dataset,
    params: &'a ForestParams,
    bagger: Bagger,
    sampler: FeatureSampler,
    stats: IoStats,
}

impl<'a> SliqTrainer<'a> {
    pub fn new(ds: &'a Dataset, params: &'a ForestParams, stats: IoStats) -> Self {
        Self {
            ds,
            params,
            bagger: Bagger::new(params.seed, params.bagging),
            sampler: FeatureSampler::new(
                params.seed,
                ds.num_features(),
                params.candidates_for(ds.num_features()),
                params.feature_sampling,
            ),
            stats,
        }
    }

    pub fn stats(&self) -> &IoStats {
        &self.stats
    }

    /// Peak class-list memory in bytes: n × (label + leaf id) = 8n.
    pub fn class_list_bytes(&self) -> u64 {
        self.ds.num_rows() as u64 * 8
    }

    /// Train one tree. Presorting (PS) is charged as one read+write pass
    /// per numerical column.
    pub fn train_tree(&self, tree_idx: u32) -> Tree {
        let ds = self.ds;
        let n = ds.num_rows();
        let labels = ds.labels();
        let weights: Vec<u32> = (0..n)
            .map(|i| self.bagger.weight(tree_idx, i as u64))
            .collect();

        // Presort numerical attributes (PS): read raw + write sorted.
        let sorted: Vec<Option<Vec<crate::data::column::SortedEntry>>> = (0..ds.num_features())
            .map(|j| match ds.column(j) {
                Column::Numerical(_) => {
                    self.stats.add_disk_read(n as u64 * 4);
                    self.stats.add_read_pass();
                    self.stats.add_disk_write(n as u64 * 8);
                    self.stats.add_write_pass();
                    Some(ds.column(j).presort())
                }
                _ => None,
            })
            .collect();

        // Class list: label + leaf, one entry per in-bag sample.
        let mut class_list: Vec<ClassEntry> = (0..n)
            .map(|i| ClassEntry {
                label: labels[i],
                leaf: if weights[i] > 0 { 1 } else { 0 },
            })
            .collect();

        let mut root_hist = Histogram::new(ds.num_classes());
        for i in 0..n {
            if weights[i] > 0 {
                root_hist.add(labels[i], weights[i]);
            }
        }
        let root_counts = root_hist.into_counts();
        let mut tree = Tree::new_root(root_counts.clone());
        let mut open_nodes: Vec<u32> = if self.params.child_open(&root_counts, 0) {
            vec![0]
        } else {
            vec![]
        };
        let mut depth = 0u32;

        while !open_nodes.is_empty() {
            let leaf_totals: Vec<Histogram> = open_nodes
                .iter()
                .map(|&id| Histogram::from_counts(tree.nodes[id as usize].class_counts.clone()))
                .collect();
            // Candidate features this level (union across leaves).
            let mut union_cols: Vec<usize> = open_nodes
                .iter()
                .flat_map(|&id| self.sampler.candidates(tree_idx, depth, id))
                .collect();
            union_cols.sort_unstable();
            union_cols.dedup();

            // Per-leaf candidate masks.
            let leaf_candidates: Vec<Vec<usize>> = open_nodes
                .iter()
                .map(|&id| self.sampler.candidates(tree_idx, depth, id))
                .collect();

            let mut best: Vec<Option<SplitCandidate>> = vec![None; open_nodes.len()];
            for &j in &union_cols {
                let mask: Vec<bool> = leaf_candidates.iter().map(|c| c.contains(&j)).collect();
                let is_candidate = |h: u32| mask[(h - 1) as usize];
                let sample2node = |i: u32| class_list[i as usize].leaf;
                let bag = |i: u32| weights[i as usize];
                let cands = match ds.column(j) {
                    Column::Numerical(_) => {
                        // SLIQ re-reads the full attribute list: n × (value
                        // + record index) bytes, one pass — including
                        // records in closed leaves (no pruning).
                        self.stats.add_disk_read(n as u64 * 8);
                        self.stats.add_read_pass();
                        numerical::best_numerical_supersplit(
                            j,
                            sorted[j].as_ref().unwrap(),
                            labels,
                            ds.num_classes(),
                            &leaf_totals,
                            self.params.score_kind,
                            sample2node,
                            is_candidate,
                            bag,
                        )
                    }
                    Column::Categorical { values, arity } => {
                        self.stats.add_disk_read(n as u64 * 4);
                        self.stats.add_read_pass();
                        categorical::best_categorical_supersplit(
                            j,
                            values,
                            *arity,
                            labels,
                            ds.num_classes(),
                            &leaf_totals,
                            self.params.score_kind,
                            sample2node,
                            is_candidate,
                            bag,
                        )
                    }
                };
                for (leaf, cand) in cands.into_iter().enumerate() {
                    if let Some(c) = cand {
                        best[leaf] = pick_best([best[leaf].take(), Some(c)].into_iter().flatten());
                    }
                }
            }

            // Split the tree + update the class list (random-access
            // writes: one label-column pass reading the winning feature).
            let mut next_rank = 0u32;
            let mut rank_map: Vec<(u32, u32)> = Vec::with_capacity(open_nodes.len()); // (left,right) new ranks
            let mut next_nodes = Vec::new();
            for (leaf, cand) in best.iter().enumerate() {
                match cand {
                    None => rank_map.push((0, 0)),
                    Some(c) => {
                        let node_id = open_nodes[leaf];
                        let (l, r) = tree.split_node(
                            node_id,
                            c.condition.clone(),
                            c.gain,
                            c.left_counts.clone(),
                            c.right_counts.clone(),
                        );
                        let lo = self.params.child_open(&c.left_counts, depth + 1);
                        let ro = self.params.child_open(&c.right_counts, depth + 1);
                        let lr = if lo {
                            next_rank += 1;
                            next_nodes.push(l);
                            next_rank
                        } else {
                            0
                        };
                        let rr = if ro {
                            next_rank += 1;
                            next_nodes.push(r);
                            next_rank
                        } else {
                            0
                        };
                        rank_map.push((lr, rr));
                    }
                }
            }
            // Evaluate winning conditions sample-by-sample (random access
            // into the raw columns; SLIQ updates the class list in place).
            self.stats.add_disk_read(n as u64 * 4);
            self.stats.add_read_pass();
            for i in 0..n {
                let leaf = class_list[i].leaf;
                if leaf == 0 {
                    continue;
                }
                let (lr, rr) = rank_map[(leaf - 1) as usize];
                let new = match &best[(leaf - 1) as usize] {
                    None => 0,
                    Some(c) => {
                        let goes_left = match &c.condition {
                            Condition::NumLe { feature, threshold } => {
                                ds.column(*feature).as_numerical()[i] <= *threshold
                            }
                            Condition::CatIn { feature, set } => {
                                set.contains(ds.column(*feature).as_categorical()[i])
                            }
                        };
                        if goes_left {
                            lr
                        } else {
                            rr
                        }
                    }
                };
                class_list[i].leaf = new;
            }
            open_nodes = next_nodes;
            depth += 1;
        }
        // Silence "field never read" on label: it is the data layout cost
        // we account for.
        let _ = class_list.first().map(|e| e.label);
        tree
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::classic::ClassicTrainer;
    use crate::data::synthetic::{Family, SyntheticSpec};
    use crate::rng::BaggingMode;

    #[test]
    fn sliq_matches_classic_tree() {
        let ds = SyntheticSpec::new(Family::Majority { informative: 3 }, 400, 6, 9).generate();
        let params = ForestParams {
            num_trees: 1,
            max_depth: 6,
            bagging: BaggingMode::Poisson,
            seed: 21,
            ..Default::default()
        };
        let sliq_tree = SliqTrainer::new(&ds, &params, IoStats::new()).train_tree(0);
        let classic_tree = ClassicTrainer::new(&ds, &params).train_tree(0);
        assert_eq!(sliq_tree, classic_tree, "SLIQ must be exact");
    }

    #[test]
    fn sliq_reads_more_than_it_needs() {
        // The cost signature: reads scale with full n per candidate
        // feature per level, even when most records are closed.
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 500, 4, 9).generate();
        let params = ForestParams {
            num_trees: 1,
            max_depth: 8,
            bagging: BaggingMode::None,
            feature_sampling: crate::rng::FeatureSampling::All,
            seed: 3,
            ..Default::default()
        };
        let stats = IoStats::new();
        let t = SliqTrainer::new(&ds, &params, stats.clone()).train_tree(0);
        assert!(t.depth() >= 2);
        // At least (presort + per-level scans) passes.
        assert!(stats.disk_read_passes() as u32 >= 4 + t.depth() * 4);
        assert!(stats.disk_read_bytes() > 0);
    }
}
