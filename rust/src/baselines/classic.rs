//! The classic in-memory Random Forest trainer — the **exactness
//! oracle**.
//!
//! This is Table 1's "generic sequential tree, all in memory": per node
//! it gathers the node's rows, sorts each candidate numerical feature,
//! finds the best split, and physically partitions the row lists —
//! random access everywhere, nothing distributed. It shares with DRF
//! only the *decision* primitives (split scoring, tie-breaking, seeded
//! bagging/feature sampling, leaf-closing rule), so the two radically
//! different computations must produce **identical trees** — which is
//! exactly the paper's "exact distributed training" claim, enforced by
//! `rust/tests/exactness.rs`.

use crate::config::ForestParams;
use crate::data::column::{Column, SortedEntry};
use crate::data::Dataset;
use crate::rng::{Bagger, FeatureSampler};
use crate::splits::histogram::Histogram;
use crate::splits::scorer::pick_best;
use crate::splits::{categorical, numerical, SplitCandidate};
use crate::tree::{Condition, Tree};

/// Classic (single-process, row-partitioning) trainer.
pub struct ClassicTrainer<'a> {
    ds: &'a Dataset,
    params: &'a ForestParams,
    bagger: Bagger,
    sampler: FeatureSampler,
}

impl<'a> ClassicTrainer<'a> {
    pub fn new(ds: &'a Dataset, params: &'a ForestParams) -> Self {
        Self {
            ds,
            params,
            bagger: Bagger::new(params.seed, params.bagging),
            sampler: FeatureSampler::new(
                params.seed,
                ds.num_features(),
                params.candidates_for(ds.num_features()),
                params.feature_sampling,
            ),
        }
    }

    /// Train the whole forest sequentially.
    pub fn train_forest(&self) -> Vec<Tree> {
        (0..self.params.num_trees as u32)
            .map(|t| self.train_tree(t))
            .collect()
    }

    /// Train one tree breadth-first (nodes numbered identically to the
    /// distributed builder).
    pub fn train_tree(&self, tree_idx: u32) -> Tree {
        let n = self.ds.num_rows();
        let labels = self.ds.labels();
        let weights: Vec<u32> = (0..n)
            .map(|i| self.bagger.weight(tree_idx, i as u64))
            .collect();

        // Root: in-bag rows in increasing order.
        let root_rows: Vec<u32> = (0..n as u32).filter(|&i| weights[i as usize] > 0).collect();
        let mut root_hist = Histogram::new(self.ds.num_classes());
        for &i in &root_rows {
            root_hist.add(labels[i as usize], weights[i as usize]);
        }
        let root_counts = root_hist.into_counts();
        let mut tree = Tree::new_root(root_counts.clone());

        // BFS over depth levels, leaves in creation order.
        let mut open: Vec<(u32, Vec<u32>)> = if self.params.child_open(&root_counts, 0) {
            vec![(0, root_rows)]
        } else {
            vec![]
        };
        let mut depth = 0u32;
        while !open.is_empty() {
            let mut next_open = Vec::new();
            for (node_id, rows) in std::mem::take(&mut open) {
                match self.best_split_for_node(tree_idx, depth, node_id, &rows, &tree, &weights)
                {
                    None => {} // leaf closes
                    Some(best) => {
                        let (left_rows, right_rows) = self.partition(&rows, &best.condition);
                        let (l, r) = tree.split_node(
                            node_id,
                            best.condition.clone(),
                            best.gain,
                            best.left_counts.clone(),
                            best.right_counts.clone(),
                        );
                        if self.params.child_open(&best.left_counts, depth + 1) {
                            next_open.push((l, left_rows));
                        }
                        if self.params.child_open(&best.right_counts, depth + 1) {
                            next_open.push((r, right_rows));
                        }
                    }
                }
            }
            open = next_open;
            depth += 1;
        }
        tree
    }

    /// Best split of one node over its sampled candidate features.
    fn best_split_for_node(
        &self,
        tree_idx: u32,
        depth: u32,
        node_id: u32,
        rows: &[u32],
        tree: &Tree,
        weights: &[u32],
    ) -> Option<SplitCandidate> {
        let labels = self.ds.labels();
        let node_hist =
            Histogram::from_counts(tree.nodes[node_id as usize].class_counts.clone());
        let totals = [node_hist];
        let candidates = self.sampler.candidates(tree_idx, depth, node_id);
        let mut best: Option<SplitCandidate> = None;
        for j in candidates {
            let cand = match self.ds.column(j) {
                Column::Numerical(vals) => {
                    // Per-node sort — the classic O(n log n)-per-node
                    // approach. Tie-break by sample id matches the
                    // presorted global order restricted to this node.
                    let mut entries: Vec<SortedEntry> = rows
                        .iter()
                        .map(|&i| SortedEntry {
                            value: vals[i as usize],
                            sample: i,
                        })
                        .collect();
                    entries.sort_by(|a, b| {
                        a.value
                            .partial_cmp(&b.value)
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(a.sample.cmp(&b.sample))
                    });
                    numerical::best_numerical_supersplit(
                        j,
                        &entries,
                        labels,
                        self.ds.num_classes(),
                        &totals,
                        self.params.score_kind,
                        |_| 1,
                        |_| true,
                        |i| weights[i as usize],
                    )
                    .pop()
                    .flatten()
                }
                Column::Categorical { values, arity } => {
                    // Gather the node's slice of the column.
                    let sub_values: Vec<u32> =
                        rows.iter().map(|&i| values[i as usize]).collect();
                    let sub_labels: Vec<u32> =
                        rows.iter().map(|&i| labels[i as usize]).collect();
                    let rows_copy = rows.to_vec();
                    categorical::best_categorical_supersplit(
                        j,
                        &sub_values,
                        *arity,
                        &sub_labels,
                        self.ds.num_classes(),
                        &totals,
                        self.params.score_kind,
                        |_| 1,
                        |_| true,
                        move |k| weights[rows_copy[k as usize] as usize],
                    )
                    .pop()
                    .flatten()
                }
            };
            if let Some(c) = cand {
                best = pick_best([best.take(), Some(c)].into_iter().flatten());
            }
        }
        best
    }

    /// Physically partition a node's rows by a condition (row order
    /// preserved — matching the bitmap semantics of the distributed
    /// path).
    fn partition(&self, rows: &[u32], cond: &Condition) -> (Vec<u32>, Vec<u32>) {
        let mut left = Vec::new();
        let mut right = Vec::new();
        match cond {
            Condition::NumLe { feature, threshold } => {
                let vals = self.ds.column(*feature).as_numerical();
                for &i in rows {
                    if vals[i as usize] <= *threshold {
                        left.push(i);
                    } else {
                        right.push(i);
                    }
                }
            }
            Condition::CatIn { feature, set } => {
                let vals = self.ds.column(*feature).as_categorical();
                for &i in rows {
                    if set.contains(vals[i as usize]) {
                        left.push(i);
                    } else {
                        right.push(i);
                    }
                }
            }
        }
        (left, right)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{Family, SyntheticSpec};
    use crate::metrics::{accuracy, auc};
    use crate::rng::{BaggingMode, FeatureSampling};

    fn params(seed: u64) -> ForestParams {
        ForestParams {
            num_trees: 3,
            max_depth: 8,
            bagging: BaggingMode::None,
            feature_sampling: FeatureSampling::All,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn fits_xor_perfectly() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 2 }, 300, 2, 1).generate();
        let p = params(1);
        let tree = ClassicTrainer::new(&ds, &p).train_tree(0);
        let preds: Vec<u32> = (0..ds.num_rows())
            .map(|i| tree.predict_class(&ds.row(i)))
            .collect();
        assert_eq!(accuracy(&preds, ds.labels()), 1.0);
        assert!(tree.depth() <= 3);
    }

    #[test]
    fn bagged_forest_learns() {
        let train =
            SyntheticSpec::new(Family::Majority { informative: 5 }, 1500, 8, 2).generate();
        let test = SyntheticSpec::new(Family::Majority { informative: 5 }, 800, 8, 3).generate();
        let p = ForestParams {
            num_trees: 7,
            bagging: BaggingMode::Poisson,
            ..params(5)
        };
        let trees = ClassicTrainer::new(&train, &p).train_forest();
        assert_eq!(trees.len(), 7);
        let scores: Vec<f64> = (0..test.num_rows())
            .map(|i| {
                trees.iter().map(|t| t.score(&test.row(i))).sum::<f64>() / trees.len() as f64
            })
            .collect();
        assert!(auc(&scores, test.labels()) > 0.85);
    }

    #[test]
    fn handles_categorical_features() {
        // Labels depend on a categorical feature.
        let n = 400;
        let values: Vec<u32> = (0..n as u32).map(|i| i % 5).collect();
        let labels: Vec<u32> = values.iter().map(|&v| (v >= 3) as u32).collect();
        let ds = Dataset::new(
            crate::data::Schema::new(
                vec![crate::data::ColumnSpec::categorical("c", 5)],
                2,
            ),
            vec![Column::Categorical { values, arity: 5 }],
            labels,
        );
        let p = params(3);
        let tree = ClassicTrainer::new(&ds, &p).train_tree(0);
        let preds: Vec<u32> = (0..n).map(|i| tree.predict_class(&ds.row(i))).collect();
        assert_eq!(accuracy(&preds, ds.labels()), 1.0);
        assert_eq!(tree.depth(), 1, "one categorical split suffices");
    }

    #[test]
    fn respects_min_records_and_depth() {
        let ds = SyntheticSpec::new(Family::Xor { informative: 3 }, 500, 6, 1).generate();
        let p = ForestParams {
            max_depth: 2,
            min_records: 50,
            ..params(1)
        };
        let tree = ClassicTrainer::new(&ds, &p).train_tree(0);
        assert!(tree.depth() <= 2);
        for node in tree.nodes.iter().filter(|n| !n.is_leaf()) {
            assert!(node.total_count() >= 50);
        }
    }
}
