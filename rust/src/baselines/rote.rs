//! Rote learning — the paper's §4 baseline.
//!
//! "It consists in just labelling a test sample correctly if it was in
//! the training set, and randomly otherwise." We store, per distinct
//! feature vector, the training label distribution; unseen vectors score
//! 0.5 (random). With useless variables the input space explodes and
//! rote learning collapses to AUC ½ — the behaviour Figure 1 contrasts
//! against DRF.

use crate::data::dataset::Dataset;
use crate::data::schema::ColumnType;
use crate::rng::SplitMix64;
use std::collections::HashMap;

/// Rote learner: memorize exact feature vectors.
pub struct RoteLearner {
    /// feature-vector hash → (positives, total).
    table: HashMap<u64, (u64, u64)>,
    num_features: usize,
}

impl RoteLearner {
    /// Hash one row's full feature vector.
    fn row_key(ds: &Dataset, i: usize) -> u64 {
        let mut parts = Vec::with_capacity(ds.num_features());
        for (j, spec) in ds.schema().columns.iter().enumerate() {
            match spec.ctype {
                ColumnType::Numerical => {
                    parts.push(ds.row(i).numerical(j).to_bits() as u64);
                }
                ColumnType::Categorical { .. } => {
                    parts.push(ds.row(i).categorical(j) as u64);
                }
            }
        }
        SplitMix64::hash_key(&parts)
    }

    /// Memorize the training set.
    pub fn fit(ds: &Dataset) -> RoteLearner {
        let mut table: HashMap<u64, (u64, u64)> = HashMap::new();
        for i in 0..ds.num_rows() {
            let key = Self::row_key(ds, i);
            let e = table.entry(key).or_insert((0, 0));
            if ds.labels()[i] == 1 {
                e.0 += 1;
            }
            e.1 += 1;
        }
        RoteLearner {
            table,
            num_features: ds.num_features(),
        }
    }

    /// Score a test row: P(1) among memorized duplicates, else 0.5.
    pub fn score(&self, ds: &Dataset, i: usize) -> f64 {
        assert_eq!(ds.num_features(), self.num_features);
        match self.table.get(&Self::row_key(ds, i)) {
            Some(&(pos, total)) if total > 0 => pos as f64 / total as f64,
            _ => 0.5,
        }
    }

    pub fn predict_scores(&self, ds: &Dataset) -> Vec<f64> {
        (0..ds.num_rows()).map(|i| self.score(ds, i)).collect()
    }

    /// Number of distinct memorized vectors.
    pub fn table_size(&self) -> usize {
        self.table.len()
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{Family, SyntheticSpec};
    use crate::metrics::auc;

    #[test]
    fn perfect_on_seen_small_space() {
        // 4 binary features, 3000 samples: every one of the 16 vectors
        // seen many times; XOR over all 4 features (no UV) -> rote wins.
        let spec = SyntheticSpec::new(Family::Xor { informative: 4 }, 3000, 4, 1);
        let train = spec.generate();
        let test = SyntheticSpec::new(Family::Xor { informative: 4 }, 500, 4, 2).generate();
        let rote = RoteLearner::fit(&train);
        assert!(rote.table_size() <= 16);
        let a = auc(&rote.predict_scores(&test), test.labels());
        assert!(a > 0.99, "rote should nail small discrete spaces, AUC {a}");
    }

    #[test]
    fn fails_with_many_useless_variables() {
        // 2 informative + 18 UV: 2^20 vectors, nothing repeats -> AUC ~ 0.5.
        let train = SyntheticSpec::new(Family::Xor { informative: 2 }, 2000, 20, 1).generate();
        let test = SyntheticSpec::new(Family::Xor { informative: 2 }, 1000, 20, 2).generate();
        let rote = RoteLearner::fit(&train);
        let a = auc(&rote.predict_scores(&test), test.labels());
        assert!((a - 0.5).abs() < 0.05, "rote must fail with UV, AUC {a}");
    }

    #[test]
    fn scores_training_rows_exactly() {
        let train = SyntheticSpec::new(Family::Majority { informative: 3 }, 200, 3, 1).generate();
        let rote = RoteLearner::fit(&train);
        let scores = rote.predict_scores(&train);
        let a = auc(&scores, train.labels());
        assert!(a > 0.99, "training AUC should be ~1, got {a}");
    }
}
