//! Baseline algorithms: the classic in-memory trainer (the exactness
//! oracle), rote learning, and single-machine SLIQ / SPRINT
//! re-implementations with full I/O accounting (Table 1's comparators).

pub mod classic;
pub mod rote;
pub mod sliq;
pub mod sprint;
