//! `GET /metrics` listener and the matching scrape client.
//!
//! A deliberately minimal HTTP/1.0 text protocol — just enough for
//! `curl`, Prometheus, and `drf metrics` to read the registry — served
//! with the crate's usual thread-per-connection + shutdown-poke idiom
//! (see [`crate::serve::server::PredictionServer`]).

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Maximum accepted request-head size; anything larger is rejected.
const MAX_REQUEST_BYTES: usize = 16 * 1024;

/// Background `/metrics` listener over the process-global registry.
/// Dropping the server stops the accept loop.
pub struct MetricsServer {
    addr: SocketAddr,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
}

impl MetricsServer {
    /// Bind `addr` (`"127.0.0.1:0"` for an ephemeral port) and serve
    /// `GET /metrics` until dropped.
    pub fn spawn(addr: &str) -> Result<MetricsServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding metrics server to {addr}"))?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let shutdown2 = shutdown.clone();
        let accept_handle = std::thread::Builder::new()
            .name("drf-metrics-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if shutdown2.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match conn {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    // Serve inline: a scrape is one small response and
                    // the accept loop must not be blockable forever, so
                    // bound the per-connection I/O with timeouts.
                    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
                    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
                    let _ = serve_http(stream);
                }
            })?;
        Ok(MetricsServer {
            addr,
            accept_handle: Some(accept_handle),
            shutdown,
        })
    }

    /// The bound address (useful with an ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Poke the listener so the accept loop wakes and exits.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

/// `GET /healthz` body: a tiny JSON liveness document. `ok` is
/// unconditionally true — reaching the handler is the health check;
/// `role`/`uptime_s` let fleet tooling tell processes apart.
fn healthz_body() -> String {
    let (role, _, _) = super::proc_identity();
    let mut o = crate::util::Json::object();
    o.set("role", crate::util::Json::Str(role))
        .set(
            "uptime_s",
            crate::util::Json::Num(super::now_us() as f64 / 1e6),
        )
        .set("ok", crate::util::Json::Bool(true));
    o.to_string() + "\n"
}

/// Handle one HTTP exchange: `GET /metrics` renders the global
/// registry, `GET /healthz` a JSON liveness document; anything else
/// gets 404/405.
fn serve_http(mut stream: TcpStream) -> Result<()> {
    let mut head = Vec::new();
    let mut buf = [0u8; 1024];
    loop {
        let n = stream.read(&mut buf)?;
        if n == 0 {
            break;
        }
        head.extend_from_slice(&buf[..n]);
        if head.windows(4).any(|w| w == b"\r\n\r\n") {
            break;
        }
        if head.len() > MAX_REQUEST_BYTES {
            bail!("request head too large");
        }
    }
    let head = String::from_utf8_lossy(&head);
    let request_line = head.lines().next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));

    let (status, body) = if method != "GET" {
        ("405 Method Not Allowed", String::from("method not allowed\n"))
    } else if path == "/metrics" || path == "/metrics/" {
        ("200 OK", super::render())
    } else if path == "/healthz" || path == "/healthz/" {
        ("200 OK", healthz_body())
    } else {
        ("404 Not Found", String::from("try GET /metrics\n"))
    };
    let response = format!(
        "HTTP/1.0 {status}\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len(),
    );
    stream.write_all(response.as_bytes())?;
    Ok(())
}

/// Scrape `GET /metrics` from `addr` and return the body text. Used by
/// `drf metrics` and the integration tests.
pub fn scrape(addr: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to metrics endpoint {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    stream.write_all(format!("GET /metrics HTTP/1.0\r\nHost: {addr}\r\n\r\n").as_bytes())?;
    let mut response = String::new();
    stream.read_to_string(&mut response)?;
    let (head, body) = response
        .split_once("\r\n\r\n")
        .context("malformed HTTP response (no header terminator)")?;
    let status_line = head.lines().next().unwrap_or("");
    if !status_line.contains(" 200 ") {
        bail!("metrics endpoint returned: {status_line}");
    }
    Ok(body.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_round_trip_over_real_listener() {
        crate::telemetry::counter("wire_test_total").add(11);
        let server = MetricsServer::spawn("127.0.0.1:0").unwrap();
        let body = scrape(&server.addr().to_string()).unwrap();
        assert!(body.contains("wire_test_total 11"));
        assert!(body.contains("# TYPE wire_test_total counter"));
    }

    #[test]
    fn healthz_reports_liveness_json() {
        let server = MetricsServer::spawn("127.0.0.1:0").unwrap();
        let mut s = TcpStream::connect(server.addr()).unwrap();
        s.write_all(b"GET /healthz HTTP/1.0\r\n\r\n").unwrap();
        let mut r = String::new();
        s.read_to_string(&mut r).unwrap();
        assert!(r.starts_with("HTTP/1.0 200"), "{r}");
        let body = r.split_once("\r\n\r\n").unwrap().1;
        let j = crate::util::Json::parse(body.trim()).unwrap();
        assert!(j.get("ok").unwrap().as_bool().unwrap());
        assert!(j.get("role").unwrap().as_str().is_ok());
        assert!(j.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn non_metrics_paths_rejected() {
        let server = MetricsServer::spawn("127.0.0.1:0").unwrap();
        let addr = server.addr().to_string();

        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"GET /nope HTTP/1.0\r\n\r\n").unwrap();
        let mut r = String::new();
        s.read_to_string(&mut r).unwrap();
        assert!(r.starts_with("HTTP/1.0 404"));

        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"POST /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut r = String::new();
        s.read_to_string(&mut r).unwrap();
        assert!(r.starts_with("HTTP/1.0 405"));
    }

    #[test]
    fn scrape_fails_cleanly_on_dead_endpoint() {
        // Bind-then-drop to get a port that is almost surely closed.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        assert!(scrape(&addr).is_err());
    }
}
