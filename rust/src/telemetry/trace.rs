//! Merge per-process trace files into one timeline, and attribute
//! stragglers per training round.
//!
//! Every process writes its own JSONL trace stream (`--trace-out`, see
//! [`super::span`]): `proc` identity lines, `span` events, and
//! `clock_sync` offset measurements taken during connection handshakes.
//! This module is the offline half — the `drf trace` subcommand:
//!
//! * [`merge_files`] parses N per-process files, checks they belong to
//!   one trace, aligns their clocks using the recorded `clock_sync`
//!   offsets (leader-rooted BFS over the offset graph), and
//! * [`MergedTrace::chrome_json`] renders the result as Chrome
//!   trace-event JSON that Perfetto / `chrome://tracing` loads
//!   directly, while
//! * [`MergedTrace::round_rows`] / [`MergedTrace::report`] compute the
//!   per-round critical path: which worker was slowest, by how much
//!   versus the median, and which phase dominated its time.
//!
//! Clock model: a `clock_sync` event in process A's file records
//! `offset_us = B_clock − A_clock` for peer B (RPC-midpoint estimate,
//! minimum-RTT sample). Timestamps from B are mapped onto the root's
//! clock as `t − rel[B]`, where `rel` accumulates offsets along the
//! BFS path from the root. Processes with no sync path to the root are
//! left unaligned (offset 0) and reported in
//! [`MergedTrace::unaligned`].

use crate::util::Json;
use crate::Result;
use anyhow::{bail, Context};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::fmt::Write as _;
use std::path::Path;

/// One `span` event parsed back from a trace file. `t_us` is the
/// span's **end** on the emitting process's clock (events are written
/// at span drop); the start is `t_us − dur_us`.
#[derive(Debug, Clone)]
pub struct SpanEvent {
    pub phase: String,
    pub t_us: u64,
    pub dur_us: u64,
    pub span_id: u64,
    pub parent_id: u64,
    pub tid: u64,
    /// Extra numeric fields (`tree`, `depth`, …) in name order.
    pub fields: Vec<(String, f64)>,
}

impl SpanEvent {
    /// Look up a numeric field such as `tree` or `depth`.
    pub fn field(&self, name: &str) -> Option<f64> {
        self.fields
            .iter()
            .find(|(k, _)| k == name)
            .map(|&(_, v)| v)
    }
}

/// One `clock_sync` event: the emitting process measured `peer_pid`'s
/// clock to lead its own by `offset_us` (negative = peer behind).
#[derive(Debug, Clone)]
pub struct ClockSyncEvent {
    pub peer_pid: u64,
    pub offset_us: i64,
    pub rtt_us: u64,
}

/// A fully parsed per-process trace file.
#[derive(Debug, Clone)]
pub struct ProcFile {
    pub role: String,
    pub shard: Option<u64>,
    pub pid: u64,
    /// First nonzero trace id seen in the file (0 = never traced an id,
    /// which merge treats as a wildcard).
    pub trace_id: u64,
    pub spans: Vec<SpanEvent>,
    pub clock_syncs: Vec<ClockSyncEvent>,
}

impl ProcFile {
    /// Human label: `leader`, `worker/1`, `objstore`, …
    pub fn label(&self) -> String {
        match self.shard {
            Some(s) => format!("{}/{s}", self.role),
            None => self.role.clone(),
        }
    }
}

fn opt_u64(j: &Json, key: &str) -> Option<u64> {
    j.get_opt(key).and_then(|v| v.as_u64().ok())
}

/// Parse one JSONL trace file. Unknown event types are skipped so old
/// readers survive new emitters; malformed JSON lines are hard errors
/// (a trace file is machine-written — corruption means truncation or a
/// clobbered sink, both worth surfacing).
pub fn parse_file(path: &Path) -> Result<ProcFile> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace file {}", path.display()))?;
    let mut out = ProcFile {
        role: String::new(),
        shard: None,
        pid: 0,
        trace_id: 0,
        spans: Vec::new(),
        clock_syncs: Vec::new(),
    };
    let mut have_identity = false;
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .with_context(|| format!("{}:{}: bad JSON", path.display(), lineno + 1))?;
        if out.trace_id == 0 {
            if let Some(id) = opt_u64(&j, "trace_id") {
                out.trace_id = id;
            }
        }
        let event = j.get_opt("event").and_then(|e| e.as_str().ok()).unwrap_or("");
        match event {
            "proc" => {
                out.role = j.get("role")?.as_str()?.to_string();
                out.shard = j.get_opt("shard").and_then(|s| s.as_u64().ok());
                out.pid = j.get("pid")?.as_u64()?;
                have_identity = true;
            }
            "span" => {
                let proc = j.get("proc")?;
                if !have_identity {
                    out.role = proc.get("role")?.as_str()?.to_string();
                    out.shard = proc.get_opt("shard").and_then(|s| s.as_u64().ok());
                    out.pid = proc.get("pid")?.as_u64()?;
                    have_identity = true;
                }
                let mut fields = Vec::new();
                if let Json::Obj(m) = &j {
                    for (k, v) in m {
                        if matches!(
                            k.as_str(),
                            "event" | "phase" | "dur_us" | "t_us" | "trace_id" | "span_id"
                                | "parent_id" | "tid" | "proc"
                        ) {
                            continue;
                        }
                        if let Ok(n) = v.as_f64() {
                            fields.push((k.clone(), n));
                        }
                    }
                }
                out.spans.push(SpanEvent {
                    phase: j.get("phase")?.as_str()?.to_string(),
                    t_us: j.get("t_us")?.as_u64()?,
                    dur_us: j.get("dur_us")?.as_u64()?,
                    span_id: j.get("span_id")?.as_u64()?,
                    parent_id: j.get("parent_id")?.as_u64()?,
                    tid: opt_u64(&j, "tid").unwrap_or(0),
                    fields,
                });
            }
            "clock_sync" => {
                out.clock_syncs.push(ClockSyncEvent {
                    peer_pid: j.get("peer")?.get("pid")?.as_u64()?,
                    offset_us: j.get("offset_us")?.as_f64()? as i64,
                    rtt_us: j.get("rtt_us")?.as_u64()?,
                });
            }
            _ => {} // forward-compatible: skip unknown event types
        }
    }
    Ok(out)
}

/// A set of per-process trace files aligned onto one clock.
pub struct MergedTrace {
    pub files: Vec<ProcFile>,
    /// Index into `files` of the alignment root (the leader if present).
    pub root: usize,
    /// `rel[pid]` = that process's clock minus the root's clock; align
    /// a timestamp from `pid` with `t − rel[pid]`.
    pub rel: BTreeMap<u64, i64>,
    /// Pids with no `clock_sync` path to the root (left unaligned).
    pub unaligned: Vec<u64>,
}

/// Parse and align a set of per-process trace files. Rejects files
/// that carry different (nonzero) trace ids — they are different runs
/// and merging them would silently interleave unrelated work.
pub fn merge_files(paths: &[impl AsRef<Path>]) -> Result<MergedTrace> {
    if paths.is_empty() {
        bail!("no trace files given");
    }
    let files: Vec<ProcFile> = paths
        .iter()
        .map(|p| parse_file(p.as_ref()))
        .collect::<Result<_>>()?;
    let mut trace_id = 0u64;
    for (f, p) in files.iter().zip(paths) {
        if f.trace_id == 0 {
            continue;
        }
        if trace_id == 0 {
            trace_id = f.trace_id;
        } else if f.trace_id != trace_id {
            bail!(
                "mismatched trace_id: {} has {:#x}, expected {:#x} — these files \
                 come from different runs",
                p.as_ref().display(),
                f.trace_id,
                trace_id
            );
        }
    }

    // Offset graph over pids: clock_sync in A's file gives the edge
    // A → B with weight (B − A); keep the minimum-RTT measurement per
    // pair and add the reverse edge with negated weight.
    let mut edges: BTreeMap<(u64, u64), (i64, u64)> = BTreeMap::new();
    for f in &files {
        for cs in &f.clock_syncs {
            let keep = edges
                .get(&(f.pid, cs.peer_pid))
                .map_or(true, |&(_, rtt)| cs.rtt_us < rtt);
            if keep {
                edges.insert((f.pid, cs.peer_pid), (cs.offset_us, cs.rtt_us));
                edges.insert((cs.peer_pid, f.pid), (-cs.offset_us, cs.rtt_us));
            }
        }
    }
    let mut adj: BTreeMap<u64, Vec<(u64, i64)>> = BTreeMap::new();
    for (&(a, b), &(off, _)) in &edges {
        adj.entry(a).or_default().push((b, off));
    }

    let root = files
        .iter()
        .position(|f| f.role == "leader")
        .unwrap_or(0);
    let mut rel: BTreeMap<u64, i64> = BTreeMap::new();
    rel.insert(files[root].pid, 0);
    let mut queue = VecDeque::from([files[root].pid]);
    while let Some(a) = queue.pop_front() {
        let base = rel[&a];
        for &(b, off) in adj.get(&a).into_iter().flatten() {
            if !rel.contains_key(&b) {
                rel.insert(b, base + off);
                queue.push_back(b);
            }
        }
    }
    let known: BTreeSet<u64> = rel.keys().copied().collect();
    let unaligned = files
        .iter()
        .filter(|f| !known.contains(&f.pid))
        .map(|f| f.pid)
        .collect();
    Ok(MergedTrace {
        files,
        root,
        rel,
        unaligned,
    })
}

impl MergedTrace {
    fn offset_of(&self, pid: u64) -> i64 {
        self.rel.get(&pid).copied().unwrap_or(0)
    }

    /// A span's start on the root's clock, in microseconds (may be
    /// negative before the global shift is applied).
    fn aligned_start(&self, f: &ProcFile, s: &SpanEvent) -> i64 {
        s.t_us as i64 - s.dur_us as i64 - self.offset_of(f.pid)
    }

    /// Render as Chrome trace-event JSON (Perfetto /
    /// `chrome://tracing` both load it). Timestamps are shifted so the
    /// earliest span starts at 0.
    pub fn chrome_json(&self) -> Json {
        let shift = self
            .files
            .iter()
            .flat_map(|f| f.spans.iter().map(|s| self.aligned_start(f, s)))
            .min()
            .unwrap_or(0);
        let mut events = Vec::new();
        for f in &self.files {
            let mut meta = Json::object();
            meta.set("ph", Json::Str("M".into()))
                .set("name", Json::Str("process_name".into()))
                .set("pid", Json::from_u64(f.pid))
                .set("tid", Json::from_u64(0));
            let mut args = Json::object();
            args.set("name", Json::Str(f.label()));
            meta.set("args", args);
            events.push(meta);
            for s in &f.spans {
                let mut e = Json::object();
                e.set("ph", Json::Str("X".into()))
                    .set("name", Json::Str(s.phase.clone()))
                    .set("cat", Json::Str("drf".into()))
                    .set("pid", Json::from_u64(f.pid))
                    .set("tid", Json::from_u64(s.tid))
                    .set(
                        "ts",
                        Json::Num((self.aligned_start(f, s) - shift) as f64),
                    )
                    .set("dur", Json::from_u64(s.dur_us));
                let mut args = Json::object();
                args.set("span_id", Json::from_u64(s.span_id))
                    .set("parent_id", Json::from_u64(s.parent_id));
                for (k, v) in &s.fields {
                    args.set(k, Json::Num(*v));
                }
                e.set("args", args);
                events.push(e);
            }
        }
        let mut top = Json::object();
        top.set("traceEvents", Json::Arr(events))
            .set("displayTimeUnit", Json::Str("ms".into()));
        top
    }

    /// Per-round critical-path rows: one per leader `level_scan` span,
    /// attributing the round's straggler among the workers that ran
    /// spans for the same `(tree, depth)`.
    pub fn round_rows(&self) -> Vec<RoundRow> {
        let leader = &self.files[self.root];
        let mut rows = Vec::new();
        for scan in leader.spans.iter().filter(|s| s.phase == "level_scan") {
            let (tree, depth) = match (scan.field("tree"), scan.field("depth")) {
                (Some(t), Some(d)) => (t as u64, d as u64),
                _ => continue,
            };
            // Per-worker, per-phase busy time inside this round.
            let mut per_proc: BTreeMap<String, BTreeMap<String, u64>> = BTreeMap::new();
            for (i, f) in self.files.iter().enumerate() {
                if i == self.root {
                    continue;
                }
                for s in &f.spans {
                    if s.field("tree") == Some(tree as f64)
                        && s.field("depth") == Some(depth as f64)
                    {
                        *per_proc
                            .entry(f.label())
                            .or_default()
                            .entry(s.phase.clone())
                            .or_insert(0) += s.dur_us;
                    }
                }
            }
            if per_proc.is_empty() {
                continue;
            }
            let mut totals: Vec<(String, u64)> = per_proc
                .iter()
                .map(|(label, phases)| (label.clone(), phases.values().sum()))
                .collect();
            totals.sort_by(|a, b| a.1.cmp(&b.1).then_with(|| a.0.cmp(&b.0)));
            let median_us = totals[(totals.len() - 1) / 2].1;
            let (straggler, straggler_us) = totals.last().cloned().expect("nonempty");
            let dominant_phase = per_proc[&straggler]
                .iter()
                .max_by_key(|&(_, &us)| us)
                .map(|(phase, _)| phase.clone())
                .unwrap_or_default();
            rows.push(RoundRow {
                tree,
                depth,
                round_wall_us: scan.dur_us,
                straggler,
                straggler_us,
                median_us,
                gap_us: straggler_us.saturating_sub(median_us),
                blocked_frac: if scan.dur_us > 0 {
                    (straggler_us as f64 / scan.dur_us as f64).min(1.0)
                } else {
                    0.0
                },
                dominant_phase,
            });
        }
        rows
    }

    /// Aggregate busy microseconds per `(process label, phase)`.
    pub fn phase_totals(&self) -> BTreeMap<String, BTreeMap<String, u64>> {
        let mut totals: BTreeMap<String, BTreeMap<String, u64>> = BTreeMap::new();
        for f in &self.files {
            let by_phase = totals.entry(f.label()).or_default();
            for s in &f.spans {
                *by_phase.entry(s.phase.clone()).or_insert(0) += s.dur_us;
            }
        }
        totals
    }

    /// Human-readable straggler report: a per-round table followed by
    /// per-process phase totals.
    pub fn report(&self) -> String {
        let rows = self.round_rows();
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{:>5} {:>5} {:>12} {:>14} {:>10} {:>8} {:<14} {}",
            "tree", "depth", "round_ms", "straggler", "gap_ms", "blocked", "phase", ""
        );
        for r in &rows {
            let _ = writeln!(
                out,
                "{:>5} {:>5} {:>12.3} {:>14} {:>10.3} {:>7.1}% {:<14} ",
                r.tree,
                r.depth,
                r.round_wall_us as f64 / 1e3,
                r.straggler,
                r.gap_us as f64 / 1e3,
                r.blocked_frac * 100.0,
                r.dominant_phase,
            );
        }
        if rows.is_empty() {
            let _ = writeln!(out, "(no leader level_scan rounds found)");
        }
        let _ = writeln!(out);
        let _ = writeln!(out, "busy time by process and phase:");
        for (label, phases) in self.phase_totals() {
            let total: u64 = phases.values().sum();
            let _ = writeln!(out, "  {label}  ({:.3} ms total)", total as f64 / 1e3);
            let mut sorted: Vec<_> = phases.iter().collect();
            sorted.sort_by(|a, b| b.1.cmp(a.1).then_with(|| a.0.cmp(b.0)));
            for (phase, us) in sorted {
                let _ = writeln!(out, "    {phase:<18} {:>12.3} ms", *us as f64 / 1e3);
            }
        }
        if !self.unaligned.is_empty() {
            let _ = writeln!(
                out,
                "warning: no clock_sync path to root for pid(s) {:?}; their \
                 timelines are unaligned",
                self.unaligned
            );
        }
        out
    }
}

/// One row of the per-round straggler table (see
/// [`MergedTrace::round_rows`]).
#[derive(Debug, Clone, PartialEq)]
pub struct RoundRow {
    pub tree: u64,
    pub depth: u64,
    /// Leader-side `level_scan` wall time for the round.
    pub round_wall_us: u64,
    /// Label of the slowest worker this round.
    pub straggler: String,
    /// That worker's total busy time in the round.
    pub straggler_us: u64,
    /// Median worker busy time (lower median for even counts).
    pub median_us: u64,
    /// `straggler_us − median_us`: how much the round could shrink if
    /// the straggler ran at median speed.
    pub gap_us: u64,
    /// Fraction of the round's wall time spent waiting on the
    /// straggler (capped at 1).
    pub blocked_frac: f64,
    /// The straggler's most expensive phase this round.
    pub dominant_phase: String,
}

/// `drf trace merge`: parse, align, and write Chrome trace JSON.
pub fn merge_to_file(paths: &[impl AsRef<Path>], out: &Path) -> Result<MergedTrace> {
    let merged = merge_files(paths)?;
    std::fs::write(out, merged.chrome_json().to_string())
        .with_context(|| format!("writing merged trace to {}", out.display()))?;
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_lines(path: &Path, lines: &[String]) {
        std::fs::write(path, lines.join("\n") + "\n").unwrap();
    }

    fn proc_line(role: &str, shard: Option<u64>, pid: u64, trace_id: u64) -> String {
        let shard = shard.map_or("null".to_string(), |s| s.to_string());
        format!(
            r#"{{"event":"proc","role":"{role}","shard":{shard},"pid":{pid},"trace_id":{trace_id}}}"#
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn span_line(
        role: &str,
        shard: Option<u64>,
        pid: u64,
        trace_id: u64,
        phase: &str,
        span_id: u64,
        parent_id: u64,
        t_us: u64,
        dur_us: u64,
        tree: u64,
        depth: u64,
    ) -> String {
        let shard = shard.map_or("null".to_string(), |s| s.to_string());
        format!(
            r#"{{"event":"span","phase":"{phase}","dur_us":{dur_us},"trace_id":{trace_id},"span_id":{span_id},"parent_id":{parent_id},"tid":1,"proc":{{"role":"{role}","shard":{shard},"pid":{pid}}},"tree":{tree},"depth":{depth},"t_us":{t_us}}}"#
        )
    }

    fn sync_line(trace_id: u64, peer_pid: u64, offset_us: i64, rtt_us: u64) -> String {
        format!(
            r#"{{"event":"clock_sync","trace_id":{trace_id},"peer":{{"role":"worker","shard":0,"pid":{peer_pid}}},"offset_us":{offset_us},"rtt_us":{rtt_us}}}"#
        )
    }

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("drf_trace_merge_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn merge_rejects_mismatched_trace_ids() {
        let dir = tmpdir("mismatch");
        let a = dir.join("a.jsonl");
        let b = dir.join("b.jsonl");
        write_lines(&a, &[proc_line("leader", None, 1, 7)]);
        write_lines(&b, &[proc_line("worker", Some(0), 2, 8)]);
        let err = merge_files(&[&a, &b]).unwrap_err();
        assert!(err.to_string().contains("mismatched trace_id"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_aligns_clocks_via_recorded_offsets() {
        let dir = tmpdir("align");
        let leader = dir.join("leader.jsonl");
        let worker = dir.join("worker.jsonl");
        // Worker's clock leads the leader's by exactly 1s.
        write_lines(
            &leader,
            &[
                proc_line("leader", None, 1, 7),
                sync_line(7, 42, 1_000_000, 80),
                span_line("leader", None, 1, 7, "level_scan", 10, 0, 2_000, 1_800, 0, 0),
            ],
        );
        write_lines(
            &worker,
            &[
                proc_line("worker", Some(0), 42, 7),
                span_line(
                    "worker",
                    Some(0),
                    42,
                    7,
                    "find_splits",
                    11,
                    10,
                    1_001_500,
                    900,
                    0,
                    0,
                ),
            ],
        );
        let merged = merge_files(&[&leader, &worker]).unwrap();
        assert_eq!(merged.files[merged.root].role, "leader");
        assert_eq!(merged.rel[&42], 1_000_000);
        assert!(merged.unaligned.is_empty());
        // Leader span starts at 200, worker span at 600 on the aligned
        // clock; the Chrome export shifts the earliest to ts=0.
        let chrome = merged.chrome_json();
        let events = chrome.get("traceEvents").unwrap().as_arr().unwrap();
        let ts_of = |name: &str| {
            events
                .iter()
                .find(|e| {
                    e.get_opt("ph").and_then(|p| p.as_str().ok()) == Some("X")
                        && e.get("name").unwrap().as_str().unwrap() == name
                })
                .unwrap()
                .get("ts")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        assert_eq!(ts_of("level_scan"), 0.0);
        // Unaligned the worker span would start at 1_000_600; aligned
        // it lands 400us into the leader's scan.
        assert_eq!(ts_of("find_splits"), 400.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn round_rows_name_the_straggler_and_its_phase() {
        let dir = tmpdir("rows");
        let leader = dir.join("leader.jsonl");
        let w0 = dir.join("w0.jsonl");
        let w1 = dir.join("w1.jsonl");
        write_lines(
            &leader,
            &[
                proc_line("leader", None, 1, 9),
                sync_line(9, 2, 0, 50),
                sync_line(9, 3, 0, 50),
                span_line("leader", None, 1, 9, "level_scan", 20, 0, 5_000, 1_000, 0, 0),
            ],
        );
        write_lines(
            &w0,
            &[
                proc_line("worker", Some(0), 2, 9),
                span_line("worker", Some(0), 2, 9, "find_splits", 21, 20, 4_500, 400, 0, 0),
            ],
        );
        write_lines(
            &w1,
            &[
                proc_line("worker", Some(1), 3, 9),
                span_line("worker", Some(1), 3, 9, "find_splits", 22, 20, 4_900, 900, 0, 0),
            ],
        );
        let merged = merge_files(&[&leader, &w0, &w1]).unwrap();
        let rows = merged.round_rows();
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert_eq!((r.tree, r.depth), (0, 0));
        assert_eq!(r.round_wall_us, 1_000);
        assert_eq!(r.straggler, "worker/1");
        assert_eq!(r.straggler_us, 900);
        assert_eq!(r.median_us, 400);
        assert_eq!(r.gap_us, 500);
        assert_eq!(r.dominant_phase, "find_splits");
        assert!((r.blocked_frac - 0.9).abs() < 1e-9);
        let report = merged.report();
        assert!(report.contains("worker/1"), "{report}");
        assert!(report.contains("find_splits"), "{report}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
