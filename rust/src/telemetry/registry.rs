//! Process-global metrics registry: counters, gauges, and log₂-bucketed
//! histograms, rendered in the Prometheus text exposition format.
//!
//! Everything here is in-tree (no new crates): atomics for the hot-path
//! instruments, `BTreeMap` keyed maps so [`Registry::render`] is
//! deterministic, and a `OnceLock` for the process-global instance.
//!
//! Metric identity is `(name, labels)`. Labels are canonicalised into a
//! single sorted string at registration time so the same label set in a
//! different order maps to the same series.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Number of histogram buckets: bucket `i < 64` covers values
/// `<= 2^i - 1`; bucket 64 is the `+Inf` overflow bucket.
pub const NUM_BUCKETS: usize = 65;

/// Bucket index for a value: 0 for 0, else `64 - leading_zeros`, i.e.
/// the number of significant bits. `bucket_index(1) == 1`,
/// `bucket_index(2) == 2`, `bucket_index(3) == 2`, ...
pub fn bucket_index(v: u64) -> usize {
    (u64::BITS - v.leading_zeros()) as usize
}

/// Inclusive upper bound (`le`) of bucket `i`, or `None` for `+Inf`.
pub fn bucket_le(i: usize) -> Option<u64> {
    if i < 64 {
        Some((1u64 << i).wrapping_sub(1))
    } else {
        None
    }
}

/// Monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, v: u64) {
        self.value.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge.
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Log₂-bucketed histogram. `observe` is lock-free (one fetch_add on
/// the bucket, one on the sum); counts per series are derived from the
/// bucket array at render time.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; NUM_BUCKETS],
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Sum of all observed values.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Total number of observations (derived from the buckets).
    pub fn count(&self) -> u64 {
        self.buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum()
    }

    /// Per-bucket counts (non-cumulative).
    pub fn bucket_counts(&self) -> [u64; NUM_BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Merge another histogram's contents into this one (used when
    /// folding per-connection stats into process totals).
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            mine.fetch_add(theirs.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.sum
            .fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

type GaugeFn = Box<dyn Fn() -> u64 + Send + Sync>;

/// Series key: metric name plus canonicalised label string
/// (`key1="v1",key2="v2"` sorted by key, empty for no labels).
type SeriesKey = (String, String);

/// The registry itself. All maps are `BTreeMap` so `render` emits
/// series in a deterministic order regardless of registration order.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<SeriesKey, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<SeriesKey, Arc<Gauge>>>,
    gauge_fns: Mutex<BTreeMap<SeriesKey, GaugeFn>>,
    histograms: Mutex<BTreeMap<SeriesKey, Arc<Histogram>>>,
}

/// Canonicalise a label set into a stable string. Sorted by key so
/// `[("b","2"),("a","1")]` and `[("a","1"),("b","2")]` share a series.
pub fn label_string(labels: &[(&str, &str)]) -> String {
    let mut sorted: Vec<(&str, &str)> = labels.to_vec();
    sorted.sort_unstable();
    sorted
        .iter()
        .map(|(k, v)| format!("{k}=\"{v}\""))
        .collect::<Vec<_>>()
        .join(",")
}

impl Registry {
    /// Fetch or create a counter series.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let key = (name.to_string(), label_string(labels));
        Arc::clone(
            self.counters
                .lock()
                .unwrap()
                .entry(key)
                .or_insert_with(|| Arc::new(Counter::default())),
        )
    }

    /// Fetch or create a gauge series.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let key = (name.to_string(), label_string(labels));
        Arc::clone(
            self.gauges
                .lock()
                .unwrap()
                .entry(key)
                .or_insert_with(|| Arc::new(Gauge::default())),
        )
    }

    /// Register a callback-backed gauge (sampled at render time). The
    /// last registration for a series wins, so re-registering after a
    /// restart (e.g. reconnecting a pool) is safe.
    pub fn register_gauge_fn(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        f: impl Fn() -> u64 + Send + Sync + 'static,
    ) {
        let key = (name.to_string(), label_string(labels));
        self.gauge_fns.lock().unwrap().insert(key, Box::new(f));
    }

    /// Fetch or create a histogram series.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        let key = (name.to_string(), label_string(labels));
        Arc::clone(
            self.histograms
                .lock()
                .unwrap()
                .entry(key)
                .or_insert_with(|| Arc::new(Histogram::default())),
        )
    }

    /// Render every series in the Prometheus text exposition format.
    /// Output order is deterministic (sorted by metric name, then
    /// canonical label string) so tests can snapshot it.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let fmt_series = |out: &mut String, name: &str, labels: &str, value: u64| {
            if labels.is_empty() {
                out.push_str(&format!("{name} {value}\n"));
            } else {
                out.push_str(&format!("{name}{{{labels}}} {value}\n"));
            }
        };

        {
            let counters = self.counters.lock().unwrap();
            let mut last_name = "";
            for ((name, labels), c) in counters.iter() {
                if name != last_name {
                    out.push_str(&format!("# TYPE {name} counter\n"));
                    last_name = name;
                }
                fmt_series(&mut out, name, labels, c.get());
            }
        }
        {
            // Plain gauges and callback gauges share the `gauge` type;
            // merge them so a name registered both ways still renders
            // under one TYPE line.
            let gauges = self.gauges.lock().unwrap();
            let gauge_fns = self.gauge_fns.lock().unwrap();
            let mut merged: BTreeMap<&SeriesKey, u64> = BTreeMap::new();
            for (key, g) in gauges.iter() {
                merged.insert(key, g.get());
            }
            for (key, f) in gauge_fns.iter() {
                merged.insert(key, f());
            }
            let mut last_name = "";
            for ((name, labels), value) in merged {
                if name != last_name {
                    out.push_str(&format!("# TYPE {name} gauge\n"));
                    last_name = name;
                }
                fmt_series(&mut out, name, labels, value);
            }
        }
        {
            let histograms = self.histograms.lock().unwrap();
            let mut last_name = "";
            for ((name, labels), h) in histograms.iter() {
                if name != last_name {
                    out.push_str(&format!("# TYPE {name} histogram\n"));
                    last_name = name;
                }
                let counts = h.bucket_counts();
                let mut cumulative = 0u64;
                for (i, c) in counts.iter().enumerate() {
                    cumulative += c;
                    // Skip interior empty buckets to keep the output
                    // readable; always emit +Inf.
                    if *c == 0 && i < NUM_BUCKETS - 1 {
                        continue;
                    }
                    let le = match bucket_le(i) {
                        Some(le) => le.to_string(),
                        None => "+Inf".to_string(),
                    };
                    let le_label = if labels.is_empty() {
                        format!("le=\"{le}\"")
                    } else {
                        format!("{labels},le=\"{le}\"")
                    };
                    fmt_series(&mut out, &format!("{name}_bucket"), &le_label, cumulative);
                }
                fmt_series(&mut out, &format!("{name}_sum"), labels, h.sum());
                fmt_series(&mut out, &format!("{name}_count"), labels, h.count());
            }
        }
        out
    }
}

/// The process-global registry.
pub fn global() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        assert_eq!(bucket_index(u64::MAX), 64);
        // Every value lands in the bucket whose `le` covers it.
        for v in [0u64, 1, 2, 3, 7, 8, 1000, 1 << 40, u64::MAX] {
            let i = bucket_index(v);
            if let Some(le) = bucket_le(i) {
                assert!(v <= le, "v={v} le={le}");
            }
            if i > 0 {
                let prev_le = bucket_le(i - 1).unwrap();
                assert!(v > prev_le, "v={v} prev_le={prev_le}");
            }
        }
        assert_eq!(bucket_le(64), None);
        assert_eq!(bucket_le(0), Some(0));
        assert_eq!(bucket_le(10), Some(1023));
    }

    #[test]
    fn histogram_observe_and_merge() {
        let h = Histogram::default();
        h.observe(0);
        h.observe(3);
        h.observe(1000);
        assert_eq!(h.count(), 3);
        assert_eq!(h.sum(), 1003);
        let counts = h.bucket_counts();
        assert_eq!(counts[0], 1);
        assert_eq!(counts[2], 1);
        assert_eq!(counts[10], 1);

        let h2 = Histogram::default();
        h2.observe(3);
        h2.observe(u64::MAX);
        h.merge_from(&h2);
        assert_eq!(h.count(), 5);
        assert_eq!(h.bucket_counts()[2], 2);
        assert_eq!(h.bucket_counts()[64], 1);
        assert_eq!(h.sum(), 1003u64.wrapping_add(3).wrapping_add(u64::MAX));
    }

    #[test]
    fn label_canonicalisation() {
        assert_eq!(
            label_string(&[("b", "2"), ("a", "1")]),
            label_string(&[("a", "1"), ("b", "2")])
        );
        assert_eq!(label_string(&[]), "");
        assert_eq!(label_string(&[("phase", "scan")]), "phase=\"scan\"");
    }

    #[test]
    fn render_is_deterministic_under_concurrent_writers() {
        let reg = Registry::default();
        std::thread::scope(|s| {
            for t in 0..4 {
                let reg = &reg;
                s.spawn(move || {
                    for i in 0..100u64 {
                        reg.counter("t_ops_total", &[("thread", &t.to_string())])
                            .inc();
                        reg.histogram("t_latency_us", &[]).observe(i);
                        reg.gauge("t_live", &[]).set(i);
                    }
                });
            }
        });
        let a = reg.render();
        let b = reg.render();
        assert_eq!(a, b, "render must be stable once writers stop");
        assert!(a.contains("# TYPE t_ops_total counter"));
        assert!(a.contains("t_ops_total{thread=\"0\"} 100"));
        assert!(a.contains("t_ops_total{thread=\"3\"} 100"));
        assert!(a.contains("# TYPE t_latency_us histogram"));
        assert!(a.contains("t_latency_us_count 400"));
        assert!(a.contains("le=\"+Inf\"} 400"));
        // Deterministic ordering: counter section precedes histograms.
        assert!(a.find("t_ops_total").unwrap() < a.find("t_latency_us").unwrap());
    }

    #[test]
    fn render_formats_series() {
        let reg = Registry::default();
        reg.counter("c_total", &[]).add(7);
        reg.gauge("g_now", &[("k", "v")]).set(9);
        reg.register_gauge_fn("g_fn", &[], || 42);
        let h = reg.histogram("h_us", &[("op", "read")]);
        h.observe(5);
        let text = reg.render();
        assert!(text.contains("c_total 7\n"));
        assert!(text.contains("g_now{k=\"v\"} 9\n"));
        assert!(text.contains("g_fn 42\n"));
        assert!(text.contains("h_us_bucket{op=\"read\",le=\"7\"} 1\n"));
        assert!(text.contains("h_us_bucket{op=\"read\",le=\"+Inf\"} 1\n"));
        assert!(text.contains("h_us_sum{op=\"read\"} 5\n"));
        assert!(text.contains("h_us_count{op=\"read\"} 1\n"));
    }

    #[test]
    fn same_series_shared() {
        let reg = Registry::default();
        reg.counter("x_total", &[("a", "1"), ("b", "2")]).add(1);
        reg.counter("x_total", &[("b", "2"), ("a", "1")]).add(1);
        assert!(reg.render().contains("x_total{a=\"1\",b=\"2\"} 2"));
    }
}
