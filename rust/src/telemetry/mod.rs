//! Crate-wide telemetry plane: metrics registry, phase tracing, and a
//! `/metrics` endpoint for every long-running process.
//!
//! Three pieces, all in-tree (no new crates):
//!
//! - [`registry`] — a process-global registry of counters, gauges, and
//!   log₂-bucketed histograms, rendered in the Prometheus text
//!   exposition format ([`render`]).
//! - [`span`] — phase-tracing drop-guards (the [`crate::span!`] macro)
//!   that record wall-time per training phase into the
//!   `drf_phase_us{phase=...}` histogram and can stream JSONL events to
//!   a `--trace-out` file ([`set_trace_out`]).
//! - [`server`] — a minimal `GET /metrics` TCP listener
//!   ([`MetricsServer`]) plus the matching [`scrape`] client used by
//!   `drf metrics ADDR [--watch]`.
//!
//! Instrumentation is observation-only by design: nothing here feeds
//! back into training decisions, so telemetry-on and telemetry-off runs
//! produce bit-identical forests (asserted by the integration tests).
//! The metric name catalog lives in `docs/observability.md`.

pub mod registry;
pub mod server;
pub mod span;
pub mod trace;

pub use registry::{bucket_index, bucket_le, Counter, Gauge, Histogram, Registry, NUM_BUCKETS};
pub use server::{scrape, MetricsServer};
pub use span::{
    adopt_remote_context, clear_trace_out, clock_sync_exchange, current_context, ensure_trace_id,
    now_us, proc_identity, record_clock_sync, set_proc_identity, set_trace_out, time_sync_reply,
    trace_enabled, trace_id, PeerClock, Span, TimeSyncReply, TraceContext, PHASE_HISTOGRAM,
};

use crate::data::io_stats::IoStats;
use std::sync::Arc;

/// Unlabelled counter from the global registry.
pub fn counter(name: &str) -> Arc<Counter> {
    registry::global().counter(name, &[])
}

/// Labelled counter from the global registry.
pub fn counter_with(name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
    registry::global().counter(name, labels)
}

/// Unlabelled gauge from the global registry.
pub fn gauge(name: &str) -> Arc<Gauge> {
    registry::global().gauge(name, &[])
}

/// Unlabelled histogram from the global registry.
pub fn histogram(name: &str) -> Arc<Histogram> {
    registry::global().histogram(name, &[])
}

/// Labelled histogram from the global registry.
pub fn histogram_with(name: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
    registry::global().histogram(name, labels)
}

/// Register a callback-backed gauge on the global registry.
pub fn register_gauge_fn(
    name: &str,
    labels: &[(&str, &str)],
    f: impl Fn() -> u64 + Send + Sync + 'static,
) {
    registry::global().register_gauge_fn(name, labels, f);
}

/// Render the global registry in the Prometheus text format.
pub fn render() -> String {
    registry::global().render()
}

/// Mirror a live [`IoStats`] into callback gauges named
/// `<prefix>_{disk_read_bytes, disk_write_bytes, disk_read_passes,
/// disk_write_passes, net_bytes, net_messages, net_broadcasts}`. The
/// gauges sample the shared atomics at scrape time, so a `/metrics`
/// reader sees I/O totals move mid-train.
pub fn register_io_gauges(prefix: &str, stats: &IoStats) {
    let stats = stats.clone();
    register_io_gauges_with(prefix, move || stats.clone());
}

/// [`register_io_gauges`] through a level of indirection: `current`
/// resolves the [`IoStats`] at every scrape, so a process that swaps
/// its stats handle mid-life (a worker reloading a re-cut shard pack)
/// keeps reporting the live counters rather than the original ones.
pub fn register_io_gauges_with(
    prefix: &str,
    current: impl Fn() -> IoStats + Send + Sync + Clone + 'static,
) {
    type Getter = fn(&IoStats) -> u64;
    const FIELDS: [(&str, Getter); 7] = [
        ("disk_read_bytes", IoStats::disk_read_bytes),
        ("disk_write_bytes", IoStats::disk_write_bytes),
        ("disk_read_passes", IoStats::disk_read_passes),
        ("disk_write_passes", IoStats::disk_write_passes),
        ("net_bytes", IoStats::net_bytes),
        ("net_messages", IoStats::net_messages),
        ("net_broadcasts", IoStats::net_broadcasts),
    ];
    for (field, getter) in FIELDS {
        let current = current.clone();
        register_gauge_fn(&format!("{prefix}_{field}"), &[], move || {
            getter(&current())
        });
    }
}

/// Total seconds recorded so far for one phase of [`PHASE_HISTOGRAM`]
/// (e.g. `"level_scan"`). Benches read this before/after a run to
/// derive per-phase time columns.
pub fn phase_seconds(phase: &str) -> f64 {
    histogram_with(PHASE_HISTOGRAM, &[("phase", phase)]).sum() as f64 / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_gauges_track_live_stats() {
        let stats = IoStats::new();
        register_io_gauges("t_io", &stats);
        stats.add_disk_read(100);
        stats.add_net(40);
        let text = render();
        assert!(text.contains("t_io_disk_read_bytes 100"));
        assert!(text.contains("t_io_net_bytes 40"));
        // Gauges are live: later writes show up on the next render.
        stats.add_disk_read(11);
        assert!(render().contains("t_io_disk_read_bytes 111"));
    }

    #[test]
    fn phase_seconds_reads_histogram_sum() {
        histogram_with(PHASE_HISTOGRAM, &[("phase", "t_phase_sum")]).observe(2_500_000);
        assert!((phase_seconds("t_phase_sum") - 2.5).abs() < 1e-9);
        assert_eq!(phase_seconds("t_phase_never_used"), 0.0);
    }
}
