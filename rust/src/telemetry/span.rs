//! Phase-tracing spans: drop-guards that record wall-time per training
//! phase into the [`crate::telemetry`] registry and optionally stream
//! structured JSONL events to a trace file (`--trace-out`).
//!
//! The hot-path cost when tracing is off is one `Instant::now()` per
//! span plus a relaxed atomic load — measured well under the crate's
//! 2% rows/s budget at per-level granularity.

use crate::util::Json;
use std::fs::File;
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Histogram that every span records into, labelled `phase="<name>"`.
/// Values are microseconds.
pub const PHASE_HISTOGRAM: &str = "drf_phase_us";

static TRACE_ON: AtomicBool = AtomicBool::new(false);
static TRACE_SINK: Mutex<Option<File>> = Mutex::new(None);

/// Process start reference for trace timestamps (monotonic, so trace
/// files are reproducible modulo durations — no wall-clock reads).
fn process_start() -> Instant {
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Direct the JSONL trace stream at `path` (truncates). Spans emit one
/// event object per line: `{"event":"span","phase":...,"t_us":...,
/// "dur_us":..., <fields...>}`.
pub fn set_trace_out(path: &Path) -> std::io::Result<()> {
    process_start(); // pin t=0 before the first event
    let f = File::create(path)?;
    *TRACE_SINK.lock().unwrap() = Some(f);
    TRACE_ON.store(true, Ordering::Release);
    Ok(())
}

/// Stop streaming trace events and close the sink.
pub fn clear_trace_out() {
    TRACE_ON.store(false, Ordering::Release);
    *TRACE_SINK.lock().unwrap() = None;
}

/// Whether a `--trace-out` sink is active.
pub fn trace_enabled() -> bool {
    TRACE_ON.load(Ordering::Acquire)
}

/// A timed phase. Created by [`Span::enter`] / the [`crate::span!`]
/// macro; on drop it observes its elapsed microseconds into
/// [`PHASE_HISTOGRAM`] and, if tracing is on, appends a JSONL event.
#[must_use = "a span records its phase time when dropped"]
pub struct Span {
    phase: &'static str,
    fields: Vec<(&'static str, u64)>,
    start: Instant,
}

impl Span {
    pub fn enter(phase: &'static str) -> Span {
        Span::enter_with(phase, &[])
    }

    pub fn enter_with(phase: &'static str, fields: &[(&'static str, u64)]) -> Span {
        Span {
            phase,
            fields: fields.to_vec(),
            start: Instant::now(),
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let dur_us = self.start.elapsed().as_micros() as u64;
        super::histogram_with(PHASE_HISTOGRAM, &[("phase", self.phase)]).observe(dur_us);
        if trace_enabled() {
            emit_span(self.phase, &self.fields, dur_us);
        }
    }
}

fn emit_span(phase: &str, fields: &[(&'static str, u64)], dur_us: u64) {
    let t_us = process_start().elapsed().as_micros() as u64;
    let mut o = Json::object();
    o.set("event", Json::Str("span".into()))
        .set("phase", Json::Str(phase.into()))
        .set("t_us", Json::from_u64(t_us))
        .set("dur_us", Json::from_u64(dur_us));
    for (k, v) in fields {
        o.set(k, Json::from_u64(*v));
    }
    let line = o.to_string();
    let mut sink = TRACE_SINK.lock().unwrap();
    if let Some(f) = sink.as_mut() {
        // Unbuffered per-event write: trace volume is per-phase (tens
        // of events per tree), not per-row, so syscall cost is noise.
        let _ = writeln!(f, "{line}");
    }
}

/// Enter a phase-tracing span: `span!("level_scan", tree = t, depth = d)`.
/// Binds to a `_span` guard dropped at end of scope; field values are
/// coerced to `u64`.
#[macro_export]
macro_rules! span {
    ($phase:expr) => {
        $crate::telemetry::Span::enter($phase)
    };
    ($phase:expr, $($k:ident = $v:expr),+ $(,)?) => {
        $crate::telemetry::Span::enter_with(
            $phase,
            &[$((stringify!($k), ($v) as u64)),+],
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_records_phase_time() {
        {
            let _s = crate::span!("test_phase_a", tree = 3usize, depth = 2usize);
        }
        let h = crate::telemetry::histogram_with(PHASE_HISTOGRAM, &[("phase", "test_phase_a")]);
        assert!(h.count() >= 1);
    }

    #[test]
    fn trace_sink_emits_jsonl() {
        let dir = std::env::temp_dir().join(format!("drf_trace_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        set_trace_out(&path).unwrap();
        assert!(trace_enabled());
        {
            let _s = crate::span!("test_phase_b", tree = 1usize);
        }
        clear_trace_out();
        assert!(!trace_enabled());
        let text = std::fs::read_to_string(&path).unwrap();
        let line = text
            .lines()
            .find(|l| l.contains("test_phase_b"))
            .expect("span event present");
        let j = Json::parse(line).unwrap();
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "span");
        assert_eq!(j.get("phase").unwrap().as_str().unwrap(), "test_phase_b");
        assert!(j.get("dur_us").is_ok());
        assert!(j.get("t_us").is_ok());
        assert_eq!(j.get("tree").unwrap().as_u64().unwrap(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
