//! Phase-tracing spans: drop-guards that record wall-time per training
//! phase into the [`crate::telemetry`] registry and optionally stream
//! structured JSONL events to a trace file (`--trace-out`).
//!
//! The hot-path cost when tracing is off is one `Instant::now()` per
//! span plus a relaxed atomic load — measured well under the crate's
//! 2% rows/s budget at per-level granularity (the tracing-overhead row
//! in `BENCH_train.json` backs the claim with data and fails the bench
//! past 5% in smoke mode).
//!
//! # Distributed tracing
//!
//! With a sink active, spans additionally carry **trace context**:
//!
//! * a process-global `trace_id` (adopted from the first remote peer
//!   that sends one, or generated lazily on the process that starts the
//!   trace — the leader);
//! * a per-span `span_id` and the `parent_id` it nests under. Parents
//!   come from a thread-local span stack, or — on RPC-serving threads —
//!   from the remote caller's context installed with
//!   [`adopt_remote_context`], which is how a worker's `find_splits`
//!   span parents under the leader's `level_scan` round span across
//!   process boundaries;
//! * the process identity `proc: {role, shard, pid}` set once by
//!   [`set_proc_identity`].
//!
//! Every process timestamps events on its **own monotonic clock**
//! (`t_us` since process start), so cross-process alignment needs the
//! clock offsets estimated by the RPC-midpoint TimeSync exchange
//! ([`clock_sync_exchange`]) and recorded as `clock_sync` events;
//! `drf trace merge` ([`super::trace`]) uses them to stitch per-process
//! files onto one timeline.

use crate::util::Json;
use std::cell::{Cell, RefCell};
use std::fs::File;
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Histogram that every span records into, labelled `phase="<name>"`.
/// Values are microseconds.
pub const PHASE_HISTOGRAM: &str = "drf_phase_us";

static TRACE_ON: AtomicBool = AtomicBool::new(false);
static TRACE_SINK: Mutex<Option<File>> = Mutex::new(None);
/// The process-global trace id (0 = unassigned). All ids stay under
/// 2^53 so they survive the JSON number model exactly.
static TRACE_ID: AtomicU64 = AtomicU64::new(0);
static NEXT_SPAN: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
/// `(role, shard)` of this process, for the `proc` field of every
/// trace event (and the `/healthz` liveness reply).
static PROC_IDENT: Mutex<Option<(String, Option<u64>)>> = Mutex::new(None);

thread_local! {
    /// Open span ids on this thread, innermost last.
    static SPAN_STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
    /// Remote parent span installed by [`adopt_remote_context`] for the
    /// duration of serving one RPC (0 = none).
    static REMOTE_PARENT: Cell<u64> = const { Cell::new(0) };
    /// Stable small per-thread lane id for the merged timeline.
    static TID: Cell<u64> = const { Cell::new(0) };
}

/// Process start reference for trace timestamps (monotonic, so trace
/// files are reproducible modulo durations — no wall-clock reads).
fn process_start() -> Instant {
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Microseconds since process start — the per-process trace clock that
/// `t_us` fields and the TimeSync exchange are expressed in.
pub fn now_us() -> u64 {
    process_start().elapsed().as_micros() as u64
}

fn thread_tid() -> u64 {
    TID.with(|t| {
        if t.get() == 0 {
            t.set(NEXT_TID.fetch_add(1, Ordering::Relaxed));
        }
        t.get()
    })
}

fn next_span_id() -> u64 {
    // 20 pid bits over 32 counter bits: unique within a process, very
    // likely unique across a fleet, and always < 2^52 (exact in JSON).
    let pid = (std::process::id() as u64) & 0xF_FFFF;
    let n = NEXT_SPAN.fetch_add(1, Ordering::Relaxed) & 0xFFFF_FFFF;
    (pid << 32) | n
}

// ---------------------------------------------------------------------
// Process identity + trace id
// ---------------------------------------------------------------------

/// Declare what this process is (`"leader"`, `"worker"`, `"objstore"`,
/// `"serve"`, …) and which shard it serves, for the `proc` field of
/// every trace event, the TimeSync reply, and `/healthz`. Call once at
/// startup, before [`set_trace_out`].
pub fn set_proc_identity(role: &str, shard: Option<u64>) {
    *PROC_IDENT.lock().unwrap() = Some((role.to_string(), shard));
}

/// This process's `(role, shard, pid)`; role defaults to `"unknown"`.
pub fn proc_identity() -> (String, Option<u64>, u32) {
    let g = PROC_IDENT.lock().unwrap();
    match g.as_ref() {
        Some((role, shard)) => (role.clone(), *shard, std::process::id()),
        None => ("unknown".to_string(), None, std::process::id()),
    }
}

/// The process-global trace id (0 until assigned/adopted).
pub fn trace_id() -> u64 {
    TRACE_ID.load(Ordering::Relaxed)
}

/// The trace id, generating one if the process has none yet. The
/// leader calls this (via [`current_context`]) when it first puts
/// context on the wire; peers adopt the incoming id instead.
pub fn ensure_trace_id() -> u64 {
    let cur = TRACE_ID.load(Ordering::Relaxed);
    if cur != 0 {
        return cur;
    }
    let micros = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(1);
    let pid = std::process::id() as u64;
    let id = ((micros ^ (pid << 40)) & ((1u64 << 52) - 1)).max(1);
    match TRACE_ID.compare_exchange(0, id, Ordering::Relaxed, Ordering::Relaxed) {
        Ok(_) => id,
        Err(existing) => existing,
    }
}

// ---------------------------------------------------------------------
// Trace context on the wire
// ---------------------------------------------------------------------

/// The `(trace_id, parent_span)` pair that rides along RPC requests so
/// the callee's spans parent under the caller's current span. Optional
/// on every protocol: a context-free frame is byte-identical to the
/// pre-tracing encoding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceContext {
    /// The caller's trace id (nonzero).
    pub trace_id: u64,
    /// The caller's innermost open span (0 = no open span).
    pub parent_span: u64,
}

/// The context to attach to an outgoing RPC: `None` when tracing is
/// off (no wire bytes), otherwise the trace id plus this thread's
/// innermost open span.
pub fn current_context() -> Option<TraceContext> {
    if !trace_enabled() {
        return None;
    }
    let parent = SPAN_STACK
        .with(|s| s.borrow().last().copied())
        .unwrap_or_else(|| REMOTE_PARENT.with(|r| r.get()));
    Some(TraceContext {
        trace_id: ensure_trace_id(),
        parent_span: parent,
    })
}

/// Restores the previous remote parent when the RPC finishes.
#[must_use = "dropping the guard immediately un-adopts the context"]
pub struct RemoteContextGuard {
    prev: u64,
}

/// Install `ctx` as this thread's span parent for the duration of the
/// returned guard — RPC-serving threads wrap request handling in this
/// so local spans parent under the remote caller's span. Also adopts
/// the caller's trace id if this process has none yet. `None` clears
/// the parent for the guard's scope (a context-free request must not
/// inherit a stale parent from the previous request on the thread).
pub fn adopt_remote_context(ctx: Option<&TraceContext>) -> RemoteContextGuard {
    let prev = REMOTE_PARENT.with(|r| r.get());
    let next = match ctx {
        Some(c) => {
            if c.trace_id != 0 {
                let _ =
                    TRACE_ID.compare_exchange(0, c.trace_id, Ordering::Relaxed, Ordering::Relaxed);
            }
            c.parent_span
        }
        None => 0,
    };
    REMOTE_PARENT.with(|r| r.set(next));
    RemoteContextGuard { prev }
}

impl Drop for RemoteContextGuard {
    fn drop(&mut self) {
        REMOTE_PARENT.with(|r| r.set(self.prev));
    }
}

// ---------------------------------------------------------------------
// Clock alignment (TimeSync)
// ---------------------------------------------------------------------

/// What a peer reports in a TimeSync reply: its identity and its own
/// trace clock at the moment it served the request. Shared by all
/// three wire protocols (coordinator, objstore, serve).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TimeSyncReply {
    /// Peer's role string (see [`set_proc_identity`]).
    pub role: String,
    /// Peer's shard id, if it serves one.
    pub shard: Option<u64>,
    /// Peer's OS process id.
    pub pid: u64,
    /// Peer's [`now_us`] when it served the request.
    pub t_us: u64,
}

/// The reply this process sends to a TimeSync request.
pub fn time_sync_reply() -> TimeSyncReply {
    let (role, shard, pid) = proc_identity();
    TimeSyncReply {
        role,
        shard,
        pid: pid as u64,
        t_us: now_us(),
    }
}

/// One measured peer clock: the peer's identity plus the estimated
/// offset of its trace clock relative to ours (`peer_t ≈ our_t +
/// offset_us` at the same instant) and the RTT of the best sample.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PeerClock {
    /// Peer's role string.
    pub role: String,
    /// Peer's shard id, if any.
    pub shard: Option<u64>,
    /// Peer's OS process id.
    pub pid: u64,
    /// `peer_clock − our_clock`, estimated at the RPC midpoint.
    pub offset_us: i64,
    /// Round-trip time of the kept (minimum-RTT) sample.
    pub rtt_us: u64,
}

/// Run a `rounds`-trip RPC-midpoint clock-offset exchange: each round
/// times one TimeSync round trip and estimates `offset = peer_t − (t0
/// + rtt/2)`; the minimum-RTT sample wins (NTP's discipline — the
/// tightest round trip bounds the midpoint error by `rtt/2`).
pub fn clock_sync_exchange<E>(
    rounds: u32,
    mut roundtrip: impl FnMut() -> std::result::Result<TimeSyncReply, E>,
) -> std::result::Result<PeerClock, E> {
    let mut best: Option<PeerClock> = None;
    for _ in 0..rounds.max(1) {
        let t0 = now_us();
        let r = roundtrip()?;
        let rtt = now_us().saturating_sub(t0);
        if best.as_ref().map_or(true, |b| rtt < b.rtt_us) {
            best = Some(PeerClock {
                role: r.role,
                shard: r.shard,
                pid: r.pid,
                offset_us: r.t_us as i64 - (t0 + rtt / 2) as i64,
                rtt_us: rtt,
            });
        }
    }
    Ok(best.expect("at least one round ran"))
}

/// Record a measured peer clock into the trace stream as a
/// `clock_sync` event (no-op when tracing is off). `drf trace merge`
/// reads these to align per-process timelines.
pub fn record_clock_sync(peer: &PeerClock) {
    if !trace_enabled() {
        return;
    }
    let mut o = Json::object();
    let mut p = Json::object();
    p.set("role", Json::Str(peer.role.clone()))
        .set(
            "shard",
            peer.shard.map(Json::from_u64).unwrap_or(Json::Null),
        )
        .set("pid", Json::from_u64(peer.pid));
    o.set("event", Json::Str("clock_sync".into()))
        .set("trace_id", Json::from_u64(trace_id()))
        .set("peer", p)
        .set("offset_us", Json::Num(peer.offset_us as f64))
        .set("rtt_us", Json::from_u64(peer.rtt_us));
    emit_event(o);
}

// ---------------------------------------------------------------------
// Sink plumbing
// ---------------------------------------------------------------------

/// Direct the JSONL trace stream at `path` (truncates). The first line
/// is a `proc` identity event; spans then emit one event object per
/// line (see the module docs for the schema).
pub fn set_trace_out(path: &Path) -> std::io::Result<()> {
    process_start(); // pin t=0 before the first event
    let f = File::create(path)?;
    *TRACE_SINK.lock().unwrap() = Some(f);
    TRACE_ON.store(true, Ordering::Release);
    let (role, shard, pid) = proc_identity();
    let mut o = Json::object();
    o.set("event", Json::Str("proc".into()))
        .set("role", Json::Str(role))
        .set("shard", shard.map(Json::from_u64).unwrap_or(Json::Null))
        .set("pid", Json::from_u64(pid as u64))
        .set("trace_id", Json::from_u64(trace_id()));
    emit_event(o);
    Ok(())
}

/// Stop streaming trace events and close the sink.
pub fn clear_trace_out() {
    TRACE_ON.store(false, Ordering::Release);
    *TRACE_SINK.lock().unwrap() = None;
}

/// Whether a `--trace-out` sink is active.
pub fn trace_enabled() -> bool {
    TRACE_ON.load(Ordering::Acquire)
}

/// Serialize `o` (plus a `t_us` stamp) to the sink. The stamp is taken
/// **under the sink lock**, which is what makes `t_us` monotone
/// non-decreasing per process even with concurrent emitters.
fn emit_event(mut o: Json) {
    let mut sink = TRACE_SINK.lock().unwrap();
    if let Some(f) = sink.as_mut() {
        o.set("t_us", Json::from_u64(now_us()));
        // Unbuffered per-event write: trace volume is per-phase (tens
        // of events per tree), not per-row, so syscall cost is noise.
        let _ = writeln!(f, "{}", o.to_string());
    }
}

// ---------------------------------------------------------------------
// Span
// ---------------------------------------------------------------------

/// A timed phase. Created by [`Span::enter`] / the [`crate::span!`]
/// macro; on drop it observes its elapsed microseconds into
/// [`PHASE_HISTOGRAM`] and, if tracing is on, appends a JSONL event
/// carrying the span's trace ids and process identity.
#[must_use = "a span records its phase time when dropped"]
pub struct Span {
    phase: &'static str,
    fields: Vec<(&'static str, u64)>,
    start: Instant,
    /// `(span_id, parent_id)` when tracing was on at enter (the id is
    /// then on this thread's span stack until drop).
    ids: Option<(u64, u64)>,
}

impl Span {
    pub fn enter(phase: &'static str) -> Span {
        Span::enter_with(phase, &[])
    }

    pub fn enter_with(phase: &'static str, fields: &[(&'static str, u64)]) -> Span {
        let ids = if trace_enabled() {
            let parent = SPAN_STACK
                .with(|s| s.borrow().last().copied())
                .unwrap_or_else(|| REMOTE_PARENT.with(|r| r.get()));
            let id = next_span_id();
            SPAN_STACK.with(|s| s.borrow_mut().push(id));
            Some((id, parent))
        } else {
            None
        };
        Span {
            phase,
            fields: fields.to_vec(),
            start: Instant::now(),
            ids,
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let dur_us = self.start.elapsed().as_micros() as u64;
        super::histogram_with(PHASE_HISTOGRAM, &[("phase", self.phase)]).observe(dur_us);
        if let Some((id, parent)) = self.ids {
            // Pop this span's id even if the sink closed mid-span.
            SPAN_STACK.with(|s| {
                let mut stack = s.borrow_mut();
                if let Some(pos) = stack.iter().rposition(|&x| x == id) {
                    stack.remove(pos);
                }
            });
            if trace_enabled() {
                emit_span(self.phase, &self.fields, dur_us, id, parent);
            }
        }
    }
}

fn emit_span(phase: &str, fields: &[(&'static str, u64)], dur_us: u64, id: u64, parent: u64) {
    let (role, shard, pid) = proc_identity();
    let mut p = Json::object();
    p.set("role", Json::Str(role))
        .set("shard", shard.map(Json::from_u64).unwrap_or(Json::Null))
        .set("pid", Json::from_u64(pid as u64));
    let mut o = Json::object();
    o.set("event", Json::Str("span".into()))
        .set("phase", Json::Str(phase.into()))
        .set("dur_us", Json::from_u64(dur_us))
        .set("trace_id", Json::from_u64(trace_id()))
        .set("span_id", Json::from_u64(id))
        .set("parent_id", Json::from_u64(parent))
        .set("tid", Json::from_u64(thread_tid()))
        .set("proc", p);
    for (k, v) in fields {
        o.set(k, Json::from_u64(*v));
    }
    emit_event(o);
}

/// Enter a phase-tracing span: `span!("level_scan", tree = t, depth = d)`.
/// Binds to a `_span` guard dropped at end of scope; field values are
/// coerced to `u64`.
#[macro_export]
macro_rules! span {
    ($phase:expr) => {
        $crate::telemetry::Span::enter($phase)
    };
    ($phase:expr, $($k:ident = $v:expr),+ $(,)?) => {
        $crate::telemetry::Span::enter_with(
            $phase,
            &[$((stringify!($k), ($v) as u64)),+],
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_records_phase_time() {
        {
            let _s = crate::span!("test_phase_a", tree = 3usize, depth = 2usize);
        }
        let h = crate::telemetry::histogram_with(PHASE_HISTOGRAM, &[("phase", "test_phase_a")]);
        assert!(h.count() >= 1);
    }

    #[test]
    fn trace_sink_emits_jsonl() {
        let dir = std::env::temp_dir().join(format!("drf_trace_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        set_trace_out(&path).unwrap();
        assert!(trace_enabled());
        {
            let _s = crate::span!("test_phase_b", tree = 1usize);
        }
        clear_trace_out();
        assert!(!trace_enabled());
        let text = std::fs::read_to_string(&path).unwrap();
        let line = text
            .lines()
            .find(|l| l.contains("test_phase_b"))
            .expect("span event present");
        let j = Json::parse(line).unwrap();
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "span");
        assert_eq!(j.get("phase").unwrap().as_str().unwrap(), "test_phase_b");
        assert!(j.get("dur_us").is_ok());
        assert!(j.get("t_us").is_ok());
        assert_eq!(j.get("tree").unwrap().as_u64().unwrap(), 1);
        // Distributed-tracing fields are present and well-formed.
        assert!(j.get("span_id").unwrap().as_u64().unwrap() > 0);
        assert!(j.get("parent_id").is_ok());
        let proc = j.get("proc").unwrap();
        assert!(proc.get("pid").unwrap().as_u64().unwrap() > 0);
        assert!(proc.get("role").unwrap().as_str().is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn nested_spans_parent_locally_and_adopt_remote_context() {
        let dir = std::env::temp_dir().join(format!("drf_trace_nest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        set_trace_out(&path).unwrap();
        {
            let _outer = crate::span!("test_nest_outer");
            let _inner = crate::span!("test_nest_inner");
        }
        // A "served RPC": the remote caller's span becomes the parent
        // of spans opened while the guard is live, and stops being the
        // parent once it drops.
        let remote = TraceContext {
            trace_id: ensure_trace_id(),
            parent_span: 0x1234_5678,
        };
        {
            let _g = adopt_remote_context(Some(&remote));
            let _s = crate::span!("test_nest_adopted");
        }
        {
            let _s = crate::span!("test_nest_unparented");
        }
        clear_trace_out();
        let text = std::fs::read_to_string(&path).unwrap();
        let find = |phase: &str| -> Json {
            Json::parse(
                text.lines()
                    .find(|l| l.contains(phase))
                    .unwrap_or_else(|| panic!("{phase} event missing")),
            )
            .unwrap()
        };
        let outer = find("test_nest_outer");
        let inner = find("test_nest_inner");
        assert_eq!(
            inner.get("parent_id").unwrap().as_u64().unwrap(),
            outer.get("span_id").unwrap().as_u64().unwrap(),
            "inner span parents under the enclosing span"
        );
        let adopted = find("test_nest_adopted");
        assert_eq!(
            adopted.get("parent_id").unwrap().as_u64().unwrap(),
            0x1234_5678,
            "adopted remote context parents the served span"
        );
        let unparented = find("test_nest_unparented");
        assert_eq!(unparented.get("parent_id").unwrap().as_u64().unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trace_file_t_us_is_monotone_even_across_threads() {
        let dir = std::env::temp_dir().join(format!("drf_trace_mono_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        set_trace_out(&path).unwrap();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for _ in 0..50 {
                        let _s = crate::span!("test_mono");
                    }
                });
            }
        });
        clear_trace_out();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut last = 0u64;
        let mut seen = 0usize;
        for line in text.lines() {
            let j = Json::parse(line).unwrap();
            let t = j.get("t_us").unwrap().as_u64().unwrap();
            assert!(t >= last, "t_us must be monotone non-decreasing per process");
            last = t;
            seen += 1;
        }
        assert!(seen >= 200, "all concurrent spans landed in the file");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reserved_json_characters_in_identity_escape_correctly() {
        let dir = std::env::temp_dir().join(format!("drf_trace_esc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        // A role full of reserved JSON characters must round-trip.
        let weird = "we\"ird\\role\nwith\ttabs";
        set_proc_identity(weird, Some(3));
        set_trace_out(&path).unwrap();
        {
            let _s = crate::span!("test_escape");
        }
        clear_trace_out();
        set_proc_identity("unknown", None);
        let text = std::fs::read_to_string(&path).unwrap();
        let line = text.lines().find(|l| l.contains("test_escape")).unwrap();
        let j = Json::parse(line).expect("reserved characters must escape to valid JSON");
        assert_eq!(
            j.get("proc").unwrap().get("role").unwrap().as_str().unwrap(),
            weird
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn clock_sync_exchange_estimates_known_offset() {
        // A fake peer whose clock runs exactly 5s ahead of ours.
        let peer = clock_sync_exchange::<std::convert::Infallible>(4, || {
            Ok(TimeSyncReply {
                role: "worker".into(),
                shard: Some(1),
                pid: 42,
                t_us: now_us() + 5_000_000,
            })
        })
        .unwrap();
        assert_eq!(peer.pid, 42);
        let err = (peer.offset_us - 5_000_000).abs();
        assert!(
            err <= 50_000,
            "midpoint estimate within 50ms of the true 5s offset, got {err}us off"
        );
    }
}
