//! End-to-end driver — the paper's §5 experiment on the Leo-like
//! dataset (3 numerical + 69 categorical features, arities 2..10'000,
//! ~5% positives), scaled 1:~50'000 to one CPU core.
//!
//! Reproduces the *shape* of Table 2 (train time / leaves / node
//! density / sample density across 1% / 10% / 100% subsets) and of
//! Figure 3 (per-depth time, open leaves, densities, tree & forest AUC
//! at every depth 0..max), exactly as DESIGN.md's experiment index
//! specifies. Datasets stay on disk, as in the paper ("all experiments
//! have been run with the datasets remaining on drive").
//!
//! ```text
//! cargo run --release --example leo_scale [-- --quick] [--rows N]
//! ```

use drf::config::{ForestParams, StorageMode, TrainConfig};
use drf::data::synthetic::LeoLikeSpec;
use drf::forest::RandomForest;
use drf::metrics::auc;
use drf::util::bench::{fmt_bytes, Table};
use drf::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["!quick", "rows", "trees", "depth"])?;
    let quick = args.get_bool("quick");
    let full_n = args.get_usize("rows", if quick { 40_000 } else { 300_000 })?;
    let trees = args.get_usize("trees", if quick { 2 } else { 3 })?;
    let max_depth = args.get_u32("depth", if quick { 10 } else { 14 })?;

    println!("generating Leo-like dataset: {full_n} rows, 72 features (3 num + 69 cat)…");
    let spec = LeoLikeSpec::new(full_n, 20_626);
    let full = spec.generate();
    // Held-out rows beyond the training range — same concept, fresh
    // samples (the Leo-like ground truth is seed-specific).
    let test = spec.generate_rows(full_n, (full_n / 5).max(5_000));
    let pos = full.class_counts()[1] as f64 / full.num_rows() as f64;
    println!("positive rate: {:.3} (unbalanced, like Leo)", pos);

    // Table 2: 1% / 10% / 100% subsets; min_records scales with subset
    // size, as in the paper ("reduced proportionally").
    let mut table2 = Table::new(&[
        "Leo", "Samples", "Train time (s)", "Leaves", "Node density", "Sample density", "RF AUC",
        "net",
    ]);
    let mut fig3_data: Option<(RandomForest, drf::coordinator::TrainReport)> = None;

    for (label, frac, min_records) in
        [("1%", 0.01, 10u64), ("10%", 0.1, 100), ("100%", 1.0, 1000)]
    {
        let n = ((full_n as f64) * frac) as usize;
        let ds = full.head(n);
        let min_records = (min_records as f64 * (full_n as f64 / 300_000.0))
            .round()
            .max(2.0) as u64;
        let params = ForestParams {
            num_trees: trees,
            max_depth,
            min_records,
            seed: 9,
            ..Default::default()
        };
        // The paper runs with data on drive; 82 workers -> one per column
        // here (72).
        let cfg = TrainConfig {
            forest: params,
            storage: StorageMode::Disk,
            ..Default::default()
        };
        let (forest, report) = RandomForest::train_with_config(&ds, &cfg)?;
        let a = auc(&forest.predict_scores(&test), test.labels());
        table2.row(&[
            label.into(),
            n.to_string(),
            format!("{:.2}", report.total_tree_seconds() / trees as f64),
            format!("{:.0}", forest.mean_leaves()),
            format!("{:.3}", forest.mean_node_density()),
            format!("{:.3}", forest.mean_sample_density()),
            format!("{a:.4}"),
            fmt_bytes(report.net.net_bytes),
        ]);
        if frac == 1.0 {
            fig3_data = Some((forest, report));
        }
    }

    println!("\n=== Table 2 (scaled): per-tree training metrics across subsets ===");
    table2.print();

    // Figure 3: per-depth metrics of the 100% run.
    let (forest, report) = fig3_data.unwrap();
    let mut fig3 = Table::new(&[
        "depth", "time (s)", "open leaves", "node dens", "sample dens", "tree AUC", "RF AUC",
    ]);
    let max_d = forest.trees.iter().map(|t| t.depth()).max().unwrap_or(0);
    // Per-level cumulative time (averaged over trees).
    let mut level_secs = vec![0.0f64; max_d as usize + 1];
    for tr in &report.per_tree {
        for l in &tr.levels {
            if (l.depth as usize) < level_secs.len() {
                level_secs[l.depth as usize] += l.seconds / report.per_tree.len() as f64;
            }
        }
    }
    for d in 0..=max_d {
        // Depth-truncated forest metrics (no retraining; traversal stops
        // at depth d).
        let rf_scores = forest.predict_scores_at_depth(&test, d);
        let rf_auc = auc(&rf_scores, test.labels());
        let tree0 = &forest.trees[0];
        let t_scores: Vec<f64> = (0..test.num_rows())
            .map(|i| tree0.score_at_depth(&test.row(i), d))
            .collect();
        let t_auc = auc(&t_scores, test.labels());
        // Structural metrics of the depth-d truncation.
        let leaves_at = |t: &drf::tree::Tree, d: u32| -> (f64, f64, f64) {
            let mut open = 0u64;
            let mut deep_w = 0u64;
            let mut tot_w = 0u64;
            for n in &t.nodes {
                let is_frontier = n.depth == d && (!n.is_leaf() || n.depth == d);
                if is_frontier {
                    open += 1;
                    deep_w += n.total_count();
                }
                if n.is_leaf() && n.depth <= d {
                    tot_w += n.total_count();
                } else if n.depth == d {
                    tot_w += n.total_count();
                }
            }
            let dens = open as f64 / 2f64.powi(d as i32);
            let sdens = if tot_w == 0 { 0.0 } else { deep_w as f64 / tot_w as f64 };
            (open as f64, dens, sdens)
        };
        let (open, dens, sdens) = leaves_at(tree0, d);
        fig3.row(&[
            d.to_string(),
            format!("{:.3}", level_secs.get(d as usize).copied().unwrap_or(0.0)),
            format!("{open:.0}"),
            format!("{dens:.3}"),
            format!("{sdens:.3}"),
            format!("{t_auc:.4}"),
            format!("{rf_auc:.4}"),
        ]);
    }
    println!("\n=== Figure 3 (scaled): per-depth metrics of the 100% run ===");
    fig3.print();
    println!(
        "\nExpected shape (paper §5): leaves grow ~exponentially while per-level\n\
         time stays flat (scan-dominated); RF AUC keeps rising with depth and\n\
         with more data; individual-tree AUC saturates earlier."
    );
    Ok(())
}
