//! Distributed feature importance (paper goal #5) on a needle-in-a-
//! haystack dataset: the planted informative features must dominate
//! the MDI ranking while the useless variables stay near zero.

use drf::config::ForestParams;
use drf::data::synthetic::{Family, SyntheticSpec};
use drf::forest::importance::{mdi_importance, rank_features};
use drf::forest::RandomForest;

fn main() -> anyhow::Result<()> {
    // 4 informative bits (needle: all must be 1), 12 useless variables.
    let ds = SyntheticSpec::new(Family::Needle { informative: 4 }, 40_000, 16, 3).generate();
    let pos_rate = ds.class_counts()[1] as f64 / ds.num_rows() as f64;
    println!("needle dataset: {} rows, positive rate {:.3}", ds.num_rows(), pos_rate);

    let params = ForestParams {
        num_trees: 15,
        max_depth: 10,
        seed: 9,
        ..Default::default()
    };
    let forest = RandomForest::train(&ds, &params)?;
    let imp = mdi_importance(&forest, ds.num_features());

    println!("feature importances (MDI, normalized):");
    for f in rank_features(&imp) {
        let marker = if f < 4 { "  <- planted" } else { "" };
        println!("  f{f:<2} {:>7.4}{marker}", imp[f]);
    }
    let planted: f64 = imp[..4].iter().sum();
    println!("planted features carry {:.1}% of total importance", planted * 100.0);
    assert!(planted > 0.5, "planted features must dominate");
    Ok(())
}
