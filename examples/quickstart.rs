//! Quickstart: generate data, train a distributed forest, evaluate.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use drf::config::{ForestParams, TrainConfig};
use drf::data::synthetic::{Family, SyntheticSpec};
use drf::forest::RandomForest;
use drf::metrics::auc;

fn main() -> anyhow::Result<()> {
    // 1. A synthetic binary-classification dataset: majority vote of 5
    //    informative binary features + 5 useless variables.
    let train = SyntheticSpec::new(Family::Majority { informative: 5 }, 20_000, 10, 1).generate();
    let test = SyntheticSpec::new(Family::Majority { informative: 5 }, 5_000, 10, 2).generate();

    // 2. Train 10 trees with the distributed runtime (one splitter per
    //    column, depth-wise DRF training, seeded bagging).
    let params = ForestParams {
        num_trees: 10,
        max_depth: 12,
        seed: 42,
        ..Default::default()
    };
    let cfg = TrainConfig {
        forest: params,
        ..Default::default()
    };
    let (forest, report) = RandomForest::train_with_config(&train, &cfg)?;

    // 3. Evaluate.
    let test_auc = auc(&forest.predict_scores(&test), test.labels());
    println!("trained {} trees in {:.2}s", forest.num_trees(), report.wall_seconds);
    println!(
        "  mean leaves/tree: {:.0}, network: {} KB in {} messages",
        forest.mean_leaves(),
        report.net.net_bytes / 1000,
        report.net.net_messages
    );
    println!("  test AUC = {test_auc:.4}");
    assert!(test_auc > 0.95, "quickstart sanity check");

    // 4. Models round-trip as JSON.
    let dir = drf::util::tempdir()?;
    let path = dir.path().join("forest.json");
    forest.save(&path)?;
    let back = RandomForest::load(&path)?;
    assert_eq!(forest, back);
    println!("  model JSON roundtrip OK ({} bytes)", std::fs::metadata(&path)?.len());

    // 5. Compile for serving: the flattened engine scores in cache-
    //    friendly batches (this is what `drf serve` runs behind TCP)
    //    and stays bit-identical to the reference traversal.
    let flat = drf::serve::FlatForest::compile(&forest);
    let batched = flat.predict_scores_batch(&test, &drf::serve::BatchOptions::default());
    let reference = forest.predict_scores_reference(&test);
    assert_eq!(batched.len(), reference.len());
    assert!(
        batched
            .iter()
            .zip(&reference)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "flat batch scores must be bit-identical to the reference"
    );
    println!(
        "  serving engine: {} nodes flattened into {} KB, batch scores exact",
        flat.num_nodes(),
        flat.nbytes() / 1000
    );
    Ok(())
}
