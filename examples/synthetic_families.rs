//! Figure 1 / Figure 2 workloads: AUC and training time as a function
//! of training-set size, number of trees, and useless variables (UV),
//! on the paper's synthetic families — with the rote-learning baseline.
//!
//! ```text
//! cargo run --release --example synthetic_families [-- --quick]
//! ```

use drf::baselines::rote::RoteLearner;
use drf::config::{ForestParams, TrainConfig};
use drf::data::synthetic::{Family, SyntheticSpec};
use drf::forest::RandomForest;
use drf::metrics::{auc, Stopwatch};
use drf::util::bench::Table;
use drf::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["!quick", "rows"])?;
    let quick = args.get_bool("quick");
    let sizes: Vec<usize> = if quick {
        vec![1_000, 10_000]
    } else {
        vec![1_000, 10_000, 100_000]
    };
    let tree_counts: &[usize] = if quick { &[1, 10] } else { &[1, 3, 10] };

    // (family, informative, total features) — low-UV and high-UV
    // variants of each ground truth, as in Figure 1's rows.
    let configs = [
        ("xor", Family::Xor { informative: 3 }, 3usize),
        ("xor+UV", Family::Xor { informative: 3 }, 12),
        ("majority", Family::Majority { informative: 5 }, 5),
        ("majority+UV", Family::Majority { informative: 5 }, 14),
        ("needle", Family::Needle { informative: 4 }, 4),
        ("needle+UV", Family::Needle { informative: 4 }, 13),
    ];

    let mut fig1 = Table::new(&["family", "n", "trees", "AUC", "-log(1-AUC)", "rote AUC"]);
    let mut fig2 = Table::new(&["family", "n", "trees", "train s", "s/tree"]);

    for (name, family, features) in configs {
        for &n in &sizes {
            let train = SyntheticSpec::new(family, n, features, 1).generate();
            let test_n = (n / 2).clamp(500, 20_000);
            let test = SyntheticSpec::new(family, test_n, features, 2).generate();
            let rote = RoteLearner::fit(&train);
            let rote_auc = auc(&rote.predict_scores(&test), test.labels());

            for &t in tree_counts {
                // Paper Fig 1: m' = ceil(sqrt(m)), unlimited depth, min
                // 1 record per leaf; workers = dimension.
                let params = ForestParams {
                    num_trees: t,
                    max_depth: 64,
                    min_records: 1,
                    seed: 7,
                    ..Default::default()
                };
                let cfg = TrainConfig {
                    forest: params,
                    ..Default::default()
                };
                let sw = Stopwatch::start();
                let (forest, _) = RandomForest::train_with_config(&train, &cfg)?;
                let secs = sw.seconds();
                let a = auc(&forest.predict_scores(&test), test.labels());
                fig1.row(&[
                    name.into(),
                    n.to_string(),
                    t.to_string(),
                    format!("{a:.4}"),
                    format!("{:.2}", -(1.0 - a).max(1e-6).ln()),
                    format!("{rote_auc:.4}"),
                ]);
                fig2.row(&[
                    name.into(),
                    n.to_string(),
                    t.to_string(),
                    format!("{secs:.3}"),
                    format!("{:.3}", secs / t as f64),
                ]);
            }
        }
    }

    println!("\n=== Figure 1: AUC vs training-set size (rote baseline rightmost) ===");
    fig1.print();
    println!("\n=== Figure 2: training time vs training-set size ===");
    fig2.print();
    println!(
        "\nExpected shape (paper §4): AUC rises with n and trees; rote fails (~0.5)\n\
         whenever UV are present; time grows ~linearly in n."
    );
    Ok(())
}
