//! Exactness demo: the distributed DRF runtime and the classic
//! in-memory trainer produce *bit-identical* trees — the paper's core
//! claim, live.

use drf::baselines::classic::ClassicTrainer;
use drf::config::{ForestParams, TrainConfig};
use drf::data::synthetic::LeoLikeSpec;
use drf::forest::RandomForest;
use drf::rng::BaggingMode;

fn main() -> anyhow::Result<()> {
    // Mixed-type data (3 numerical + 69 categorical features, arities
    // up to 10'000) — the hardest exactness case.
    let ds = LeoLikeSpec::new(2_000, 7).generate();
    let params = ForestParams {
        num_trees: 3,
        max_depth: 6,
        min_records: 10,
        bagging: BaggingMode::Poisson,
        seed: 1234,
        ..Default::default()
    };

    println!("training classic in-memory forest…");
    let classic = ClassicTrainer::new(&ds, &params).train_forest();

    println!("training distributed DRF (72 splitters, depth-wise)…");
    let cfg = TrainConfig {
        forest: params,
        ..Default::default()
    };
    let (distributed, report) = RandomForest::train_with_config(&ds, &cfg)?;

    for (t, (c, d)) in classic.iter().zip(&distributed.trees).enumerate() {
        assert_eq!(c, d, "tree {t} differs!");
        println!(
            "  tree {t}: {} nodes, depth {} — identical across algorithms",
            c.num_nodes(),
            c.depth()
        );
    }
    println!(
        "EXACT: {} trees bit-identical; DRF used {} splitters and {} KB of network traffic",
        classic.len(),
        report.num_splitters,
        report.net.net_bytes / 1000
    );
    Ok(())
}
