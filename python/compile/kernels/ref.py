"""Pure-jnp oracle for the split-gain kernel (the CORE correctness
signal: pytest asserts kernel == ref across shapes and edge cases)."""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def _gini(pos, tot):
    """Binary Gini impurity 2p(1-p) with 0-total guard."""
    safe = jnp.maximum(tot, 1.0)
    p = pos / safe
    return 2.0 * p * (1.0 - p)


def split_gains_ref(pos_prefix, tot_prefix, parent_pos, parent_tot, valid):
    """Reference masked Gini gains, shape [B, T]."""
    nl = tot_prefix
    nr = parent_tot[:, None] - nl
    posl = pos_prefix
    posr = parent_pos[:, None] - posl
    n = jnp.maximum(parent_tot[:, None], 1.0)

    gain = (
        _gini(parent_pos[:, None], parent_tot[:, None])
        - (nl / n) * _gini(posl, nl)
        - (nr / n) * _gini(posr, nr)
    )
    ok = (valid > 0.0) & (nl > 0.0) & (nr > 0.0)
    return jnp.where(ok, gain, NEG_INF)


def best_split_ref(pos_prefix, tot_prefix, parent_pos, parent_tot, valid):
    """Reference (best_gain[B], best_idx[B])."""
    gains = split_gains_ref(pos_prefix, tot_prefix, parent_pos, parent_tot, valid)
    idx = jnp.argmax(gains, axis=1).astype(jnp.int32)
    best = jnp.max(gains, axis=1)
    return best, idx
