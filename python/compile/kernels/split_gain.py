"""Layer-1 Pallas kernel: masked binary-Gini split gains.

The compute hot-spot of DRF's Alg. 1 is scoring every candidate
threshold of a presorted feature against cumulative label histograms.
For binary classification the inputs per scoring *task* (= one open
leaf x feature) are the prefix counts at each candidate boundary:

    pos_prefix[b, t]  cumulative class-1 weight left of boundary t
    tot_prefix[b, t]  cumulative total weight left of boundary t
    parent_pos[b]     class-1 weight of the whole leaf
    parent_tot[b]     total weight of the whole leaf
    valid[b, t]       1.0 for real boundaries, 0.0 for padding

and the output is the Gini gain of every boundary (``-inf`` where
invalid), from which the caller takes an argmax.

TPU mapping (DESIGN.md "Hardware adaptation"): this is a pure
elementwise VPU workload. We tile (TASKS_BLK x T) f32 blocks through
VMEM with a 1-D grid over task blocks; with the default (8, 512) blocks
the working set is ~115 KiB per grid step, far under VMEM, so no
double buffering is required. ``interpret=True`` everywhere: the CPU
PJRT client cannot execute Mosaic custom-calls, and interpret mode
lowers to plain HLO that the Rust runtime loads directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tasks per VMEM block (the grid runs batch/TASKS_BLK steps).
TASKS_BLK = 8

NEG_INF = -1e30


def _gain_kernel(pos_ref, tot_ref, ppos_ref, ptot_ref, valid_ref, out_ref):
    """Compute masked binary Gini gains for one (TASKS_BLK, T) tile."""
    nl = tot_ref[...]              # (blk, T) left totals
    posl = pos_ref[...]            # (blk, T) left positives
    n = ptot_ref[...][:, None]     # (blk, 1) parent totals
    posp = ppos_ref[...][:, None]  # (blk, 1) parent positives

    nr = n - nl
    posr = posp - posl

    # Binary Gini impurity g(p) = 2 p (1 - p); guard the 0-count sides.
    safe_nl = jnp.maximum(nl, 1.0)
    safe_nr = jnp.maximum(nr, 1.0)
    safe_n = jnp.maximum(n, 1.0)
    pl_ = posl / safe_nl
    pr_ = posr / safe_nr
    pp_ = posp / safe_n
    g_left = 2.0 * pl_ * (1.0 - pl_)
    g_right = 2.0 * pr_ * (1.0 - pr_)
    g_parent = 2.0 * pp_ * (1.0 - pp_)

    gain = g_parent - (nl / safe_n) * g_left - (nr / safe_n) * g_right

    ok = (valid_ref[...] > 0.0) & (nl > 0.0) & (nr > 0.0)
    out_ref[...] = jnp.where(ok, gain, NEG_INF)


@functools.partial(jax.jit, static_argnames=())
def split_gains(pos_prefix, tot_prefix, parent_pos, parent_tot, valid):
    """Masked Gini gains, shape [B, T]; invalid entries are -inf.

    B must be a multiple of TASKS_BLK (the AOT wrapper pads).
    """
    b, t = pos_prefix.shape
    blk = min(TASKS_BLK, b)
    assert b % blk == 0, f"batch {b} not a multiple of block {blk}"
    grid = (b // blk,)
    block2 = pl.BlockSpec((blk, t), lambda i: (i, 0))
    block1 = pl.BlockSpec((blk,), lambda i: (i,))
    return pl.pallas_call(
        _gain_kernel,
        out_shape=jax.ShapeDtypeStruct((b, t), jnp.float32),
        grid=grid,
        in_specs=[block2, block2, block1, block1, block2],
        out_specs=block2,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(pos_prefix, tot_prefix, parent_pos, parent_tot, valid)
