"""AOT lowering: JAX/Pallas split scorer -> HLO text artifacts.

Run once at build time (``make artifacts``); the Rust runtime loads the
HLO text via the PJRT C API and Python never appears on the training
path.

HLO *text* (not ``HloModuleProto.serialize()``) is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids which the
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import score_batch

# (batch, thresholds) block shapes to compile. 16x512 is the runtime
# default (rust/src/coordinator/manager.rs); 4x64 keeps tests fast.
SHAPES = [(16, 512), (4, 64)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unpacks with to_tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_scorer(batch: int, thresholds: int) -> str:
    mat = jax.ShapeDtypeStruct((batch, thresholds), jnp.float32)
    vec = jax.ShapeDtypeStruct((batch,), jnp.float32)
    lowered = jax.jit(score_batch).lower(mat, mat, vec, vec, mat)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for batch, thresholds in SHAPES:
        text = lower_scorer(batch, thresholds)
        path = os.path.join(
            args.out_dir, f"split_scorer_{batch}x{thresholds}.hlo.txt"
        )
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
