"""Layer-2 JAX graph: the full batched split-scoring model.

Composes the Pallas gain kernel (Layer 1) with the argmax reduction and
returns, per task, the best boundary's gain and index. This module is
what ``aot.py`` lowers to HLO text for the Rust runtime; it never runs
at training time.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels.split_gain import split_gains


def score_batch(pos_prefix, tot_prefix, parent_pos, parent_tot, valid):
    """Best (gain, index) per task.

    Args (all f32):
      pos_prefix:  [B, T] cumulative class-1 weight per boundary.
      tot_prefix:  [B, T] cumulative total weight per boundary.
      parent_pos:  [B]    leaf class-1 weight.
      parent_tot:  [B]    leaf total weight.
      valid:       [B, T] 1.0 = real boundary, 0.0 = padding.

    Returns:
      (best_gain f32[B], best_idx i32[B]). Rows with no valid boundary
      report a large negative best_gain (callers treat gain <= 0 as "no
      split").
    """
    gains = split_gains(pos_prefix, tot_prefix, parent_pos, parent_tot, valid)
    best_idx = jnp.argmax(gains, axis=1).astype(jnp.int32)
    best_gain = jnp.max(gains, axis=1)
    return best_gain, best_idx
