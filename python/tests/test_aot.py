"""Build-path tests: lowering to HLO text succeeds, is parseable-ish,
and the artifact numerics match the jit path (executed via jax from the
same HLO module semantics)."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import SHAPES, lower_scorer, to_hlo_text
from compile.model import score_batch


def test_lowering_produces_hlo_text():
    text = lower_scorer(4, 64)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Five f32 parameters.
    assert text.count("parameter(") >= 5


def test_all_declared_shapes_lower():
    for b, t in SHAPES:
        text = lower_scorer(b, t)
        assert "HloModule" in text
        assert f"f32[{b},{t}]" in text


def test_artifacts_on_disk_match_fresh_lowering():
    # `make artifacts` output must correspond to the current source.
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    for b, t in SHAPES:
        path = os.path.join(art_dir, f"split_scorer_{b}x{t}.hlo.txt")
        if not os.path.exists(path):
            import pytest

            pytest.skip("artifacts not built (run `make artifacts`)")
        on_disk = open(path).read()
        fresh = lower_scorer(b, t)
        assert on_disk == fresh, f"stale artifact {path}: rerun `make artifacts`"


def test_jit_scorer_executes():
    b, t = 16, 512
    pos = jnp.zeros((b, t), jnp.float32)
    tot = jnp.broadcast_to(jnp.arange(1, t + 1, dtype=jnp.float32), (b, t))
    gain, idx = score_batch(
        pos, tot, jnp.zeros(b), jnp.full(b, float(t + 1)), jnp.ones((b, t))
    )
    assert gain.shape == (b,)
    assert idx.shape == (b,)
    # All-negative leaf: no positive gain anywhere.
    assert float(jnp.max(gain)) <= 1e-6
