"""Layer-1 correctness: the Pallas split-gain kernel vs the pure-jnp
oracle — the CORE correctness signal of the python build stack.

Hypothesis sweeps shapes and histogram contents; hand-built cases pin
down the edge semantics (empty sides, padding masks, ties)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import NEG_INF, best_split_ref, split_gains_ref
from compile.kernels.split_gain import split_gains
from compile.model import score_batch


def make_task(rng, t):
    """Random monotone prefix arrays for one task of T boundaries."""
    n_boundaries = rng.integers(0, t + 1)
    # Random per-boundary increments (weights>=1 between boundaries).
    tot_inc = rng.integers(1, 5, size=t)
    pos_inc = np.minimum(tot_inc, rng.integers(0, 5, size=t))
    tot = np.cumsum(tot_inc).astype(np.float32)
    pos = np.cumsum(pos_inc).astype(np.float32)
    valid = (np.arange(t) < n_boundaries).astype(np.float32)
    # Parent = prefix at the end plus a random tail.
    parent_tot = float(tot[-1]) + float(rng.integers(1, 10))
    parent_pos = min(float(pos[-1]) + float(rng.integers(0, 10)), parent_tot)
    return pos, tot, np.float32(parent_pos), np.float32(parent_tot), valid


def build_batch(seed, b, t):
    rng = np.random.default_rng(seed)
    pos = np.zeros((b, t), np.float32)
    tot = np.zeros((b, t), np.float32)
    ppos = np.zeros(b, np.float32)
    ptot = np.ones(b, np.float32)
    valid = np.zeros((b, t), np.float32)
    for i in range(b):
        pos[i], tot[i], ppos[i], ptot[i], valid[i] = make_task(rng, t)
    return pos, tot, ppos, ptot, valid


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.sampled_from([1, 2, 4, 8, 16, 24]),
    t=st.sampled_from([1, 7, 64, 130]),
)
def test_kernel_matches_ref_random(seed, b, t):
    if b % min(8, b) != 0:
        b = 8
    args = build_batch(seed, b, t)
    got = np.asarray(split_gains(*map(jnp.asarray, args)))
    want = np.asarray(split_gains_ref(*map(jnp.asarray, args)))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_model_best_matches_ref(seed):
    args = build_batch(seed, 16, 64)
    jargs = list(map(jnp.asarray, args))
    got_gain, got_idx = score_batch(*jargs)
    want_gain, want_idx = best_split_ref(*jargs)
    # Kernel and ref round differently at the ULP level (different op
    # order); gains agree to ~1e-5 relative and the *chosen* boundary,
    # re-scored by the reference, must be within that tolerance of the
    # reference optimum (near-ties may legitimately pick either index).
    np.testing.assert_allclose(
        np.asarray(got_gain), np.asarray(want_gain), rtol=1e-4, atol=1e-6
    )
    ref_gains = np.asarray(split_gains_ref(*jargs))
    for i in range(16):
        if float(want_gain[i]) <= NEG_INF / 2:
            continue
        chosen = ref_gains[i, int(got_idx[i])]
        assert chosen >= float(want_gain[i]) - 1e-6


def test_known_perfect_split():
    # One task: boundaries after each of 6 sorted records, labels
    # 0,0,0,1,1,1 -> boundary 2 (left = 3 negatives) has gain 0.5.
    pos = np.array([[0, 0, 0, 1, 2]], np.float32)
    tot = np.array([[1, 2, 3, 4, 5]], np.float32)
    ppos = np.array([3], np.float32)
    ptot = np.array([6], np.float32)
    valid = np.ones((1, 5), np.float32)
    gain, idx = score_batch(*map(jnp.asarray, (pos, tot, ppos, ptot, valid)))
    assert int(idx[0]) == 2
    np.testing.assert_allclose(float(gain[0]), 0.5, rtol=1e-6)


def test_padding_is_ignored():
    pos = np.array([[0, 1, 1, 9]], np.float32)  # junk in padded tail
    tot = np.array([[1, 2, 9, 9]], np.float32)
    ppos = np.array([1], np.float32)
    ptot = np.array([3], np.float32)
    valid = np.array([[1, 1, 0, 0]], np.float32)
    gains = np.asarray(split_gains(*map(jnp.asarray, (pos, tot, ppos, ptot, valid))))
    assert gains[0, 2] == NEG_INF and gains[0, 3] == NEG_INF
    assert gains[0, 0] > 0  # boundary 0 separates the negative


def test_empty_row_reports_neg_inf():
    pos = np.zeros((1, 4), np.float32)
    tot = np.zeros((1, 4), np.float32)
    valid = np.zeros((1, 4), np.float32)
    gain, _ = score_batch(
        *map(jnp.asarray, (pos, tot, np.ones(1, np.float32), np.ones(1, np.float32), valid))
    )
    assert float(gain[0]) <= NEG_INF / 2


def test_full_side_is_invalid():
    # Boundary where nl == n (right side empty) must be masked even if
    # marked valid.
    pos = np.array([[1, 2]], np.float32)
    tot = np.array([[2, 4]], np.float32)
    ppos = np.array([2], np.float32)
    ptot = np.array([4], np.float32)
    valid = np.ones((1, 2), np.float32)
    gains = np.asarray(split_gains(*map(jnp.asarray, (pos, tot, ppos, ptot, valid))))
    assert gains[0, 1] == NEG_INF, "nl == n boundary must be invalid"


def test_argmax_takes_first_of_ties():
    # Symmetric labels 0,1,1,0: boundaries 0 and 2 tie; argmax -> 0.
    pos = np.array([[0, 1, 2]], np.float32)
    tot = np.array([[1, 2, 3]], np.float32)
    ppos = np.array([2], np.float32)
    ptot = np.array([4], np.float32)
    valid = np.ones((1, 3), np.float32)
    _, idx = score_batch(*map(jnp.asarray, (pos, tot, ppos, ptot, valid)))
    assert int(idx[0]) == 0
